//! Foresight: the paper's adaptive layer-reuse policy (§3.4, Algorithm 1).
//!
//! Two phases per request:
//!
//! * **Warmup** (steps `0..W`): every block computes; per-site thresholds λ
//!   accumulate as the geometrically-weighted sum of the MSEs between
//!   consecutive-step features over the last three warmup steps (Eq. 5):
//!   `λ = Σ_{t=W-2..W} 10^{-(W-t)} · MSE[x(t), x(t-1)]`.
//! * **Reuse** (steps `W..T`): the step cycle has length R. On refresh
//!   steps (`(step-W) % R == 0`) everything recomputes, δ updates to
//!   `MSE[x(t), C]` (Eq. 6), the cache refreshes. On the other `N = R-1`
//!   steps each site reuses iff `δ ≤ γ·λ` (Eq. 7); sites that compute
//!   anyway also refresh δ and the cache (Alg. 1 lines 19-21).

use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

use super::{Action, CacheMode, Granularity, ReusePolicy, Site};
use crate::model::BlockKind;

/// Per-site adaptive state.
#[derive(Debug, Clone, Copy, Default)]
struct SiteState {
    lambda: f64,
    delta: f64,
}

/// The Foresight policy.
pub struct Foresight {
    /// Reuse window (display only; the cycle is driven by `r = N+1`).
    pub n: usize,
    /// Compute interval: cycle length in the reuse phase.
    pub r: usize,
    /// Threshold scaling γ ∈ (0, 2] (Eq. 7).
    pub gamma: f64,
    /// Warmup fraction of total steps (paper uses 15%).
    pub warmup_frac: f64,
    warmup_steps: usize,
    steps: usize,
    state: BTreeMap<(usize, BlockKind, usize), SiteState>,
}

impl Foresight {
    /// Validated constructor: every parameter is reachable from wire input
    /// via [`super::build_policy`], so out-of-range values must surface as
    /// request errors, never as a worker-killing panic.
    pub fn new(n: usize, r: usize, gamma: f64, warmup_frac: f64) -> Result<Self> {
        if r < 1 {
            return Err(anyhow!("foresight: compute interval r must be >= 1, got {r}"));
        }
        if !(gamma.is_finite() && gamma > 0.0) {
            return Err(anyhow!("foresight: gamma must be a finite number > 0, got {gamma}"));
        }
        if !(warmup_frac.is_finite() && (0.0..1.0).contains(&warmup_frac)) {
            return Err(anyhow!(
                "foresight: warmup must be a fraction in [0, 1), got {warmup_frac}"
            ));
        }
        Ok(Self {
            n,
            r,
            gamma,
            warmup_frac,
            warmup_steps: 0,
            steps: 0,
            state: BTreeMap::new(),
        })
    }

    /// Paper default configuration N=1, R=2, γ=0.5, W=15%.
    pub fn paper_default() -> Self {
        Self::new(1, 2, 0.5, 0.15).expect("paper defaults are valid")
    }

    fn key(site: Site) -> (usize, BlockKind, usize) {
        (site.layer, site.kind, site.branch)
    }

    pub fn warmup_steps(&self) -> usize {
        self.warmup_steps
    }

    fn in_warmup(&self, step: usize) -> bool {
        step < self.warmup_steps
    }

    fn is_refresh_step(&self, step: usize) -> bool {
        (step - self.warmup_steps) % self.r == 0
    }
}

impl ReusePolicy for Foresight {
    fn name(&self) -> String {
        format!(
            "foresight(N{}R{},g={},W={:.0}%)",
            self.n,
            self.r,
            self.gamma,
            self.warmup_frac * 100.0
        )
    }

    fn granularity(&self) -> Granularity {
        Granularity::Coarse
    }

    fn cache_mode(&self) -> CacheMode {
        CacheMode::Output
    }

    fn needs_measurement(&self) -> bool {
        true
    }

    fn begin_request(&mut self, _layers: usize, steps: usize) {
        self.steps = steps;
        // At least 3 warmup steps so Eq. 5 has its three MSE terms; at most
        // steps-1 so there is a reuse phase at all.
        self.warmup_steps = ((steps as f64 * self.warmup_frac).round() as usize)
            .clamp(3, steps.saturating_sub(1).max(3));
        self.state.clear();
    }

    fn action(&mut self, step: usize, site: Site) -> Action {
        if self.in_warmup(step) || self.is_refresh_step(step) {
            return Action::Compute { update_cache: true, measure: true };
        }
        let s = self.state.entry(Self::key(site)).or_default();
        if s.delta <= self.gamma * s.lambda {
            Action::Reuse
        } else {
            // Alg. 1 lines 19-21: computed sites refresh δ and the cache.
            Action::Compute { update_cache: true, measure: true }
        }
    }

    fn observe_mse(&mut self, step: usize, site: Site, mse: f64) {
        let w = self.warmup_steps;
        let s = self.state.entry(Self::key(site)).or_default();
        if step < w {
            // Warmup MSEs exist from step 1 (step 0 has no predecessor).
            // Eq. 5: weight 10^-(W-1-step) over the last three steps.
            if step + 3 >= w && step > 0 {
                let exponent = (w - 1 - step) as i32;
                s.lambda += mse * 10f64.powi(-exponent);
            }
            if step + 1 == w {
                // Alg. 1 line 8: δ initialised to λ.
                s.delta = s.lambda;
            }
        } else {
            // Eq. 6: δ = MSE(current features, cache).
            s.delta = mse;
        }
    }

    fn thresholds(&self) -> Option<BTreeMap<(usize, BlockKind, usize), f64>> {
        Some(
            self.state
                .iter()
                .map(|(k, v)| (*k, v.lambda))
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::Unit;

    fn site(layer: usize) -> Site {
        Site { layer, kind: BlockKind::Spatial, unit: Unit::Block, branch: 0 }
    }

    #[test]
    fn never_reuses_during_warmup() {
        let mut p = Foresight::paper_default();
        p.begin_request(4, 30);
        let w = p.warmup_steps();
        assert!(w >= 3);
        for step in 0..w {
            for l in 0..4 {
                assert!(
                    !p.action(step, site(l)).is_reuse(),
                    "reused at warmup step {step}"
                );
            }
        }
    }

    #[test]
    fn refresh_steps_always_compute() {
        let mut p = Foresight::new(1, 2, 0.5, 0.15).unwrap();
        p.begin_request(2, 30);
        let w = p.warmup_steps();
        // make reuse very attractive
        for step in 1..w {
            p.observe_mse(step, site(0), 0.0);
        }
        for step in w..30 {
            let a = p.action(step, site(0));
            if (step - w) % 2 == 0 {
                assert!(!a.is_reuse(), "refresh step {step} must compute");
            }
        }
    }

    #[test]
    fn threshold_gate_controls_reuse() {
        let mut p = Foresight::new(1, 2, 1.0, 0.15).unwrap();
        p.begin_request(2, 40);
        let w = p.warmup_steps();
        // warmup MSEs of 1.0 → λ = 1.11 (1 + 0.1 + 0.01 over last 3 steps)
        for step in 1..w {
            p.observe_mse(step, site(0), 1.0);
            p.observe_mse(step, site(1), 1.0);
        }
        let lam = p.thresholds().unwrap()[&(0, BlockKind::Spatial, 0)];
        assert!((lam - 1.11).abs() < 1e-9, "λ={lam}");

        // site 0: small δ → reuse; site 1: large δ → compute
        let refresh = w; // first refresh step
        p.observe_mse(refresh, site(0), 0.5);
        p.observe_mse(refresh, site(1), 5.0);
        let s0 = p.action(w + 1, site(0));
        let s1 = p.action(w + 1, site(1));
        assert_eq!(s0, Action::Reuse);
        assert!(!s1.is_reuse());
    }

    #[test]
    fn gamma_scales_strictness() {
        // Same δ/λ: strict gamma computes, lax gamma reuses (Table 3).
        for (gamma, expect_reuse) in [(0.25, false), (2.0, true)] {
            let mut p = Foresight::new(1, 2, gamma, 0.15).unwrap();
            p.begin_request(1, 40);
            let w = p.warmup_steps();
            for step in 1..w {
                p.observe_mse(step, site(0), 1.0);
            }
            p.observe_mse(w, site(0), 0.6); // δ=0.6 vs λ=1.11
            let a = p.action(w + 1, site(0));
            assert_eq!(a.is_reuse(), expect_reuse, "gamma={gamma}");
        }
    }

    #[test]
    fn delta_initialised_to_lambda_reuses_first_window() {
        // Right after warmup δ=λ, so with γ=1 the first reuse-eligible step
        // reuses (δ ≤ γλ).
        let mut p = Foresight::new(1, 2, 1.0, 0.15).unwrap();
        p.begin_request(1, 40);
        let w = p.warmup_steps();
        for step in 1..w {
            p.observe_mse(step, site(0), 2.0);
        }
        p.observe_mse(w, site(0), 2.0 * 1.11); // refresh-step δ update
        // δ == γλ exactly → reuse (≤)
        let a = p.action(w + 1, site(0));
        assert_eq!(a, Action::Reuse);
    }

    #[test]
    fn warmup_clamped_to_at_least_three() {
        let mut p = Foresight::new(1, 2, 0.5, 0.05).unwrap();
        p.begin_request(1, 20); // 5% of 20 = 1 → clamp to 3
        assert_eq!(p.warmup_steps(), 3);
    }

    #[test]
    fn branches_tracked_independently() {
        let mut p = Foresight::new(1, 2, 1.0, 0.15).unwrap();
        p.begin_request(1, 40);
        let w = p.warmup_steps();
        let cond = Site { branch: 0, ..site(0) };
        let uncond = Site { branch: 1, ..site(0) };
        for step in 1..w {
            p.observe_mse(step, cond, 1.0);
            p.observe_mse(step, uncond, 1.0);
        }
        p.observe_mse(w, cond, 0.1);  // cond: very reusable
        p.observe_mse(w, uncond, 9.0); // uncond: not
        assert!(p.action(w + 1, cond).is_reuse());
        assert!(!p.action(w + 1, uncond).is_reuse());
    }
}
