//! Forecast: a composable wrapper that upgrades verbatim replay to
//! linear-multistep feature prediction ("Predict to Skip", PAPERS.md).
//!
//! The wrapper owns **no** reuse schedule of its own: the inner policy
//! decides *when* a site reuses (Foresight's δ ≤ γ·λ gate, a static
//! cycle, ...), and `Forecast` upgrades each of those `Reuse` decisions
//! to [`Action::Predict`] with its fixed predictor order `k`. The engine
//! then extrapolates the site's next output from its last `k` cached
//! outputs in one fused `lms_combine` dispatch — falling back to
//! verbatim replay (counted in `forecast_fallback_units`) for any site
//! whose history ring is still shallower than `k`.
//!
//! Order `k = 1` is the degenerate predictor: its only coefficient is
//! `1.0`, so the forecast *is* the cached output. The wrapper therefore
//! passes `Reuse` through untouched at `k = 1`, making
//! `forecast:k=1,inner=<spec>` bit-identical to `<spec>` — the
//! equivalence the engine tests pin.
//!
//! Spec grammar: `forecast:k=<order>,inner=<spec>`, where `<spec>` is any
//! complete policy spec (embedded `:` and `,` included) — see
//! [`super::build_policy`].

use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

use super::{Action, CacheMode, Granularity, ReusePolicy, Site};
use crate::model::BlockKind;

/// Highest supported predictor order (matches
/// [`crate::runtime::lms_coefficients`]).
pub const MAX_ORDER: usize = 4;

/// The forecasting wrapper policy.
pub struct Forecast {
    order: usize,
    inner: Box<dyn ReusePolicy>,
}

impl Forecast {
    /// Validated constructor: `order` must be in `[1, 4]` and the inner
    /// policy must cache whole block outputs (`Coarse` granularity,
    /// `Output` mode) — extrapolating residual deltas or sublayer units
    /// is not what the predictor's coefficients model.
    pub fn new(order: usize, inner: Box<dyn ReusePolicy>) -> Result<Self> {
        if !(1..=MAX_ORDER).contains(&order) {
            return Err(anyhow!(
                "forecast: predictor order k must be in [1, {MAX_ORDER}], got {order}"
            ));
        }
        if inner.granularity() != Granularity::Coarse || inner.cache_mode() != CacheMode::Output {
            return Err(anyhow!(
                "forecast: inner policy '{}' must be coarse output-mode (whole-block \
                 outputs); fine/delta policies cannot be forecast-wrapped",
                inner.name()
            ));
        }
        Ok(Self { order, inner })
    }

    /// The predictor order k.
    pub fn order(&self) -> usize {
        self.order
    }
}

impl ReusePolicy for Forecast {
    fn name(&self) -> String {
        format!("forecast(k={},{})", self.order, self.inner.name())
    }

    fn granularity(&self) -> Granularity {
        self.inner.granularity()
    }

    fn cache_mode(&self) -> CacheMode {
        self.inner.cache_mode()
    }

    fn needs_measurement(&self) -> bool {
        self.inner.needs_measurement()
    }

    fn history_depth(&self) -> usize {
        self.order
    }

    fn begin_request(&mut self, layers: usize, steps: usize) {
        self.inner.begin_request(layers, steps);
    }

    fn action(&mut self, step: usize, site: Site) -> Action {
        match self.inner.action(step, site) {
            Action::Reuse if self.order >= 2 => Action::Predict { order: self.order },
            a => a,
        }
    }

    fn observe_mse(&mut self, step: usize, site: Site, mse: f64) {
        self.inner.observe_mse(step, site, mse);
    }

    fn thresholds(&self) -> Option<BTreeMap<(usize, BlockKind, usize), f64>> {
        self.inner.thresholds()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::Unit;
    use crate::policy::{Foresight, Pab, StaticReuse};

    fn site(layer: usize) -> Site {
        Site { layer, kind: BlockKind::Spatial, unit: Unit::Block, branch: 0 }
    }

    #[test]
    fn upgrades_inner_reuse_to_predict() {
        // static:n=1,r=2 reuses every odd step; wrapped at k=2 those
        // become Predict{2} while compute steps pass through untouched.
        let inner = Box::new(StaticReuse::new(1, 2).unwrap());
        let mut p = Forecast::new(2, inner).unwrap();
        p.begin_request(2, 10);
        let mut saw_predict = false;
        let mut saw_compute = false;
        for step in 0..10 {
            match p.action(step, site(0)) {
                Action::Predict { order } => {
                    assert_eq!(order, 2);
                    saw_predict = true;
                }
                Action::Reuse => panic!("k=2 wrapper must not emit bare Reuse"),
                Action::Compute { .. } => saw_compute = true,
                Action::ReuseResidual => panic!("coarse inner cannot emit ReuseResidual"),
            }
        }
        assert!(saw_predict && saw_compute);
    }

    #[test]
    fn order_one_is_transparent() {
        // k=1 forecasting degenerates to verbatim replay: the wrapped
        // policy's action stream must be identical to the bare policy's.
        let mut bare = StaticReuse::new(1, 2).unwrap();
        let mut wrapped = Forecast::new(1, Box::new(StaticReuse::new(1, 2).unwrap())).unwrap();
        bare.begin_request(2, 12);
        wrapped.begin_request(2, 12);
        for step in 0..12 {
            for l in 0..2 {
                assert_eq!(bare.action(step, site(l)), wrapped.action(step, site(l)));
            }
        }
        assert_eq!(wrapped.history_depth(), 1);
    }

    #[test]
    fn delegates_measurement_and_thresholds_to_inner() {
        let mut p = Forecast::new(3, Box::new(Foresight::paper_default())).unwrap();
        assert!(p.needs_measurement());
        assert_eq!(p.history_depth(), 3);
        p.begin_request(2, 30);
        for step in 1..6 {
            p.observe_mse(step, site(0), 1.0);
        }
        let th = p.thresholds().expect("foresight thresholds pass through");
        assert!(!th.is_empty());
        assert!(p.name().contains("forecast(k=3"));
        assert!(p.name().contains("foresight"));
    }

    #[test]
    fn rejects_bad_order_and_incompatible_inner() {
        assert!(Forecast::new(0, Box::new(StaticReuse::new(1, 2).unwrap())).is_err());
        assert!(Forecast::new(5, Box::new(StaticReuse::new(1, 2).unwrap())).is_err());
        // PAB is fine-grained delta caching — not forecastable.
        let pab = Pab::new(2, 4, 6, 0.07, 0.55, vec![0], 2, 30).unwrap();
        let err = Forecast::new(2, Box::new(pab)).unwrap_err().to_string();
        assert!(err.contains("coarse output-mode"), "{err}");
    }
}
