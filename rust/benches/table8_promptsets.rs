//! Reproduces **Table 8** (appendix): CLIPSIM / CLIP-Temp / DOVER-VQA on
//! the UCF-101 and EvalCrafter prompt sets, PAB vs Foresight vs baseline.
//!
//! Paper shape: Foresight holds baseline-level CLIP/VQA scores while PAB
//! degrades the VQA scores (most visibly on OpenSora), with Foresight N2R3
//! delivering the larger speedup.

use foresight::bench_support::{run_clip_vqa_suite, scaled, BenchCtx};
use foresight::util::benchkit::{MdTable, Report};
use foresight::util::stats;
use foresight::workload;

fn main() -> anyhow::Result<()> {
    let mut ctx = BenchCtx::new()?;
    let engine = ctx.engine("opensora-sim", "240p-2s")?;
    let methods: &[(&str, &str)] = &[
        ("Baseline", "none"),
        ("PAB", "pab"),
        ("Foresight (N1R2)", "foresight:n=1,r=2"),
        ("Foresight (N2R3)", "foresight:n=2,r=3"),
    ];

    let mut report = Report::new(
        "table8",
        "Table 8 — CLIP / VQA metrics on UCF-101 and EvalCrafter prompt sets (opensora-sim)",
    );

    for (set_name, prompts) in [
        ("UCF-101", workload::ucf101_prompts(scaled(101))),
        ("EvalCrafter", workload::evalcrafter_prompts(scaled(150))),
    ] {
        let rows = run_clip_vqa_suite(&engine, &prompts, methods, None)?;
        let base_lat = stats::mean(&rows[0].latencies);
        let mut t = MdTable::new(&[
            "Method", "CLIP-SIM", "CLIP-Temp", "VQA-Aesthetic", "VQA-Technical",
            "VQA-Overall", "Latency(s)", "Speedup",
        ]);
        for r in &rows {
            let lat = stats::mean(&r.latencies);
            t.row(vec![
                r.name.clone(),
                format!("{:.2}", r.clipsim),
                format!("{:.2}", r.clip_temp),
                format!("{:.2}", r.vqa_aesthetic),
                format!("{:.2}", r.vqa_technical),
                format!("{:.2}", r.vqa_overall),
                stats::fmt_mean_pm_std(&r.latencies),
                if r.name == "Baseline" {
                    "-".into()
                } else {
                    format!("{:.2}x", base_lat / lat)
                },
            ]);
        }
        report.text(&format!("\n{} prompts: {}", set_name, prompts.len()));
        report.table(set_name, &t);
        report.csv(&set_name.to_lowercase().replace('-', ""), &t);
    }
    report.finish()?;
    Ok(())
}
