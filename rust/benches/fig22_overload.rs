//! **Figure 22 (repo-original)**: SLO-aware overload control under
//! trace-driven load — bounded admission, deadlines, and
//! quality-for-latency degradation against the *real* server.
//!
//! Unlike fig20/fig21 (virtual-clock replays of the scheduler
//! discipline), this harness starts an actual [`foresight::server::Server`]
//! (one device, one worker, bounded queue, degradation armed) and replays
//! open-loop arrival traces from [`foresight::util::loadgen`] through real
//! TCP clients, so admission control, deadline sweeps and the degrade
//! valve are exercised end to end on the wire.
//!
//! Scenarios and what they pin:
//!
//! * **calm** — sequential `policy=auto` traffic with empty queues:
//!   resolves the tuned spec, never degraded (the baseline p99).
//! * **bounded admission** (deterministic) — a long request plugs the
//!   worker while `--max-queue` incompatible jobs fill the queue; the
//!   next arrival must get the `overloaded` response with a sane
//!   `retry_after_ms` hint, and the queue must never exceed the bound.
//! * **degrade valve** (deterministic) — with queue depth at the
//!   `--degrade` threshold, a `policy=auto` request must resolve to the
//!   profile's fastest frontier point *within its min-PSNR budget*
//!   (`degraded:true`, echoing `degraded_from`) — and never to the
//!   below-budget point, whatever the pressure.
//! * **bursty / flash crowd** — loadgen traces past capacity with
//!   retrying clients: every arrival ends with a definitive answer, and
//!   the flash-crowd p99 of served requests stays a bounded multiple of
//!   the calm p99 (graceful degradation, not collapse).
//! * **mixed soak** — two buckets merged with deadlines sprinkled in:
//!   after the dust settles the server must hold zero lanes, zero queued
//!   jobs, and close its books: `requests == retires + errors`, with
//!   client-side tallies matching `retires`/`deadline_misses` exactly.
//!
//! `FORESIGHT_BENCH_STEPS` overrides the step count (CI smoke mode).
//! Exits cleanly with a SKIP note when the AOT artifacts are absent.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use foresight::autotune::{ProfileKey, ProfilePoint, ProfileStore, TunedProfile};
use foresight::config::Manifest;
use foresight::runtime::DevicePool;
use foresight::server::{Backoff, Client, EngineRegistry, Server, ServerConfig};
use foresight::util::benchkit::{MdTable, Report};
use foresight::util::json::Json;
use foresight::util::loadgen::{self, Arrival};
use foresight::util::stats;

const MODEL: &str = "opensora-sim";
const BUCKETS: [&str; 2] = ["240p-2s", "240p-4s"];
/// The profile's tuned spec (what unpressured `auto` serves).
const TUNED: &str = "foresight:n=1,r=2,gamma=0.5";
/// In-budget fast tier: the degrade valve's legal target.
const FAST_GOOD: &str = "static:n=1,r=3";
/// Below-budget tier: present on the frontier, must never be served.
const FAST_BAD: &str = "static:n=1,r=6";
const MAX_BATCH: usize = 4;
const MAX_QUEUE: usize = 6;
const DEGRADE_AT: usize = 2;

fn bench_steps() -> usize {
    std::env::var("FORESIGHT_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10)
        .max(4)
}

/// A store with quality headroom: the tuned spec is *not* the fastest
/// in-budget frontier point, so the degrade valve has somewhere to go.
/// (Stores written by `foresight autotune` pick the fastest in-budget
/// point as the spec, which makes degradation a no-op by construction —
/// an operator wanting the valve hand-pins a higher-quality spec, which
/// is what this store models.)
fn headroom_store(steps: usize) -> Arc<ProfileStore> {
    let mut store = ProfileStore::new();
    let frontier = vec![
        ProfilePoint {
            spec: FAST_BAD.into(),
            wall_s: 0.5,
            reuse_fraction: 0.85,
            psnr: 22.0, // below budget: never servable
            ssim: 0.80,
            lpips: 0.30,
        },
        ProfilePoint {
            spec: FAST_GOOD.into(),
            wall_s: 1.0,
            reuse_fraction: 0.65,
            psnr: 31.0, // in budget: the degrade target
            ssim: 0.92,
            lpips: 0.12,
        },
        ProfilePoint {
            spec: TUNED.into(),
            wall_s: 2.0,
            reuse_fraction: 0.40,
            psnr: 38.0,
            ssim: 0.97,
            lpips: 0.05,
        },
    ];
    for bucket in BUCKETS {
        for sampler in ["rflow", "ddim"] {
            store.insert(TunedProfile {
                key: ProfileKey {
                    model: MODEL.into(),
                    bucket: bucket.into(),
                    sampler: sampler.into(),
                    steps,
                },
                spec: TUNED.into(),
                min_psnr: 30.0,
                profile_version: 1,
                frontier: frontier.clone(),
            });
        }
    }
    Arc::new(store)
}

fn gen_req(bucket: &str, policy: &str, prompt: &str, seed: u64, steps: usize) -> Json {
    Json::obj(vec![
        ("op", Json::str("generate")),
        ("model", Json::str(MODEL)),
        ("bucket", Json::str(bucket)),
        ("policy", Json::str(policy)),
        ("prompt", Json::str(prompt)),
        ("seed", Json::num(seed as f64)),
        ("steps", Json::num(steps as f64)),
    ])
}

fn with_deadline(mut req: Json, deadline_ms: u64) -> Json {
    if let Json::Obj(ref mut o) = req {
        o.insert("deadline_ms".into(), Json::num(deadline_ms as f64));
    }
    req
}

fn stats_op(c: &mut Client) -> Json {
    c.call(&Json::obj(vec![("op", Json::str("stats"))]))
        .expect("stats op")
}

fn get_f64(j: &Json, k: &str) -> f64 {
    j.get(k)
        .and_then(|v| v.as_f64())
        .unwrap_or_else(|| panic!("missing {k}: {j}"))
}

fn get_str<'a>(j: &'a Json, k: &str) -> &'a str {
    j.get(k)
        .and_then(|v| v.as_str())
        .unwrap_or_else(|| panic!("missing {k}: {j}"))
}

/// Poll the stats op until `pred` holds (bounds scenario setup races).
fn wait_stats(addr: &std::net::SocketAddr, what: &str, pred: impl Fn(&Json) -> bool) {
    let mut c = Client::connect(addr).expect("stats client");
    let t0 = Instant::now();
    loop {
        let s = stats_op(&mut c);
        if pred(&s) {
            return;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(60),
            "timed out waiting for {what}: {s}"
        );
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// One client's final outcome for one arrival.
struct Outcome {
    resp: Json,
    latency_s: f64,
}

/// What a scenario's outcomes amounted to, for the report/assertions.
#[derive(Default)]
struct Tally {
    ok: usize,
    deadline: usize,
    overloaded: usize,
    other_err: usize,
    latencies_ok: Vec<f64>,
}

fn tally(outcomes: &[Outcome]) -> Tally {
    let mut t = Tally::default();
    for o in outcomes {
        match get_str(&o.resp, "status") {
            "ok" => {
                t.ok += 1;
                t.latencies_ok.push(o.latency_s);
            }
            _ if o
                .resp
                .get("deadline_exceeded")
                .and_then(|v| v.as_bool())
                .unwrap_or(false) =>
            {
                t.deadline += 1
            }
            _ if foresight::server::is_overloaded(&o.resp) => t.overloaded += 1,
            _ => t.other_err += 1,
        }
    }
    t
}

/// Replay a trace open-loop: one fresh connection per arrival, retrying
/// overloaded responses per `backoff` (seeded per arrival index so jitter
/// is deterministic across runs).
fn replay_trace(
    addr: std::net::SocketAddr,
    trace: &[Arrival],
    req_for: impl Fn(usize, &Arrival) -> Json + Sync,
    backoff: &Backoff,
) -> Vec<Outcome> {
    loadgen::replay(trace, |i, a| {
        let req = req_for(i, a);
        let mut c = Client::connect(&addr).expect("client connect");
        let b = Backoff { seed: i as u64, ..backoff.clone() };
        let t0 = Instant::now();
        let resp = c.call_retrying(&req, &b).expect("transport");
        Outcome { resp, latency_s: t0.elapsed().as_secs_f64() }
    })
}

fn main() -> anyhow::Result<()> {
    let manifest = match Manifest::load(&Manifest::default_root()) {
        Ok(m) => m,
        Err(e) => {
            println!("[fig22] SKIP: artifacts unavailable ({e:#}); run `make artifacts`");
            return Ok(());
        }
    };
    let steps = bench_steps();

    let pool = Arc::new(DevicePool::cpu(1)?);
    let pairs: Vec<(String, String)> = BUCKETS
        .iter()
        .map(|b| (MODEL.to_string(), b.to_string()))
        .collect();
    let registry = Arc::new(EngineRegistry::load_pool(pool, &manifest, &pairs)?);
    let server = Server::start(
        registry,
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: 1,
            devices: 1,
            max_batch: MAX_BATCH,
            max_queue: MAX_QUEUE,
            degrade_threshold: DEGRADE_AT,
            profiles: Some(headroom_store(steps)),
            ..ServerConfig::default()
        },
    )?;
    let addr = server.addr();

    // Calibrate the service time: rates below are expressed in units of
    // one solo request so the traces stress the same relative load at
    // every FORESIGHT_BENCH_STEPS. (One warm pass first so compile/cache
    // effects don't inflate the unit.)
    let svc = {
        let mut c = Client::connect(&addr)?;
        let req = gen_req(BUCKETS[0], TUNED, "calibration", 1, steps);
        let r = c.call(&req)?;
        assert_eq!(get_str(&r, "status"), "ok", "calibration failed: {r}");
        let t0 = Instant::now();
        let r = c.call(&req)?;
        assert_eq!(get_str(&r, "status"), "ok", "{r}");
        t0.elapsed().as_secs_f64().max(0.02)
    };
    let rps = |k: f64| k / svc;

    // --- scenario: calm -------------------------------------------------
    // Sequential auto traffic against empty queues: tuned spec, no
    // degradation, the latency baseline every overload bound is relative
    // to.
    let calm = {
        let mut c = Client::connect(&addr)?;
        let mut lat = Vec::new();
        for i in 0..6u64 {
            let t0 = Instant::now();
            let r = c.call(&gen_req(BUCKETS[0], "auto", &format!("calm {i}"), 10 + i, steps))?;
            lat.push(t0.elapsed().as_secs_f64());
            assert_eq!(get_str(&r, "status"), "ok", "calm {i}: {r}");
            assert_eq!(get_str(&r, "resolved_policy"), TUNED, "calm {i}: {r}");
            assert_eq!(
                r.get("degraded").and_then(|v| v.as_bool()),
                Some(false),
                "calm traffic must never degrade: {r}"
            );
        }
        lat
    };
    let calm_p99 = stats::percentile(&calm, 99.0);

    // --- scenario: bounded admission (deterministic) --------------------
    // Plug the only worker with a long request; its cohort key fences the
    // incompatible fillers into the queue. The (MAX_QUEUE+1)-th arrival
    // must be refused on the wire, not queued.
    {
        let plug = gen_req(BUCKETS[0], TUNED, "admission plug", 90, 60.min(steps * 8));
        let mut c_plug = Client::connect(&addr)?;
        let h_plug = std::thread::spawn(move || c_plug.call(&plug).expect("plug"));
        wait_stats(&addr, "plug in flight", |s| get_f64(s, "lanes_active") >= 1.0);

        let mut fillers = Vec::new();
        for i in 0..MAX_QUEUE as u64 {
            let req = gen_req(BUCKETS[1], TUNED, &format!("filler {i}"), 100 + i, steps);
            let mut c = Client::connect(&addr)?;
            fillers.push(std::thread::spawn(move || c.call(&req).expect("filler")));
        }
        wait_stats(&addr, "queue at bound", |s| {
            get_f64(s, "queue_depth") >= MAX_QUEUE as f64
        });

        let mut c = Client::connect(&addr)?;
        let probe = gen_req(BUCKETS[1], TUNED, "one too many", 200, steps);
        let r = c.call_retrying(&probe, &Backoff::none())?;
        assert!(
            foresight::server::is_overloaded(&r),
            "arrival past --max-queue must be refused: {r}"
        );
        let hint = get_f64(&r, "retry_after_ms");
        assert!(
            (25.0..=5000.0).contains(&hint),
            "retry_after_ms outside its clamp: {r}"
        );
        assert_eq!(get_f64(&r, "queue_depth"), MAX_QUEUE as f64, "{r}");

        let plug_r = h_plug.join().expect("plug thread");
        assert_eq!(get_str(&plug_r, "status"), "ok", "{plug_r}");
        for h in fillers {
            let r = h.join().expect("filler thread");
            assert_eq!(get_str(&r, "status"), "ok", "queued filler must be served: {r}");
        }
    }

    // --- scenario: degrade valve (deterministic) ------------------------
    // Queue depth exactly at the threshold: auto must swap to FAST_GOOD
    // (in budget), echo the swap, and never touch FAST_BAD.
    {
        let plug = gen_req(BUCKETS[0], TUNED, "degrade plug", 91, 60.min(steps * 8));
        let mut c_plug = Client::connect(&addr)?;
        let h_plug = std::thread::spawn(move || c_plug.call(&plug).expect("plug"));
        wait_stats(&addr, "plug in flight", |s| get_f64(s, "lanes_active") >= 1.0);

        let mut fillers = Vec::new();
        for i in 0..DEGRADE_AT as u64 {
            let req = gen_req(BUCKETS[1], TUNED, &format!("pressure {i}"), 300 + i, steps);
            let mut c = Client::connect(&addr)?;
            fillers.push(std::thread::spawn(move || c.call(&req).expect("pressure")));
        }
        wait_stats(&addr, "queue at degrade threshold", |s| {
            get_f64(s, "queue_depth") >= DEGRADE_AT as f64
        });

        let probe = gen_req(BUCKETS[0], "auto", "degrade probe", 400, steps);
        let mut c = Client::connect(&addr)?;
        let h_probe = std::thread::spawn(move || c.call(&probe).expect("probe"));

        let r = h_probe.join().expect("probe thread");
        assert_eq!(get_str(&r, "status"), "ok", "{r}");
        assert_eq!(
            r.get("degraded").and_then(|v| v.as_bool()),
            Some(true),
            "auto under queue pressure must degrade: {r}"
        );
        assert_eq!(
            get_str(&r, "resolved_policy"),
            FAST_GOOD,
            "degrade must pick the fastest *in-budget* tier: {r}"
        );
        assert_eq!(get_str(&r, "degraded_from"), TUNED, "{r}");

        let plug_r = h_plug.join().expect("plug thread");
        assert_eq!(get_str(&plug_r, "status"), "ok", "{plug_r}");
        for h in fillers {
            let r = h.join().expect("pressure thread");
            assert_eq!(get_str(&r, "status"), "ok", "{r}");
        }

        let mut c2 = Client::connect(&addr)?;
        let s = stats_op(&mut c2);
        assert!(get_f64(&s, "degrade_swaps") >= 1.0, "{s}");
        assert!(get_f64(&s, "degrade_headroom_s") > 0.0, "{s}");
        // Pressure gone: auto resolves the tuned spec again.
        let r = c2.call(&gen_req(BUCKETS[0], "auto", "pressure off", 401, steps))?;
        assert_eq!(get_str(&r, "resolved_policy"), TUNED, "{r}");
        assert_eq!(r.get("degraded").and_then(|v| v.as_bool()), Some(false), "{r}");
    }

    let backoff = Backoff {
        attempts: 6,
        base: Duration::from_millis((svc * 250.0) as u64 + 5),
        cap: Duration::from_secs(2),
        jitter: true,
        seed: 0,
    };
    let degrade_seen = Arc::new(AtomicUsize::new(0));
    let resolved_log: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
    let audit = |r: &Json| {
        // Global degrade audit, applied to every served auto response in
        // the trace scenarios: a swap is only ever to the in-budget tier.
        if get_str(r, "status") == "ok" {
            if let Some(rp) = r.get("resolved_policy").and_then(|v| v.as_str()) {
                assert_ne!(
                    rp, FAST_BAD,
                    "served a frontier point below the min-PSNR budget: {r}"
                );
                resolved_log.lock().unwrap().push(rp.to_string());
                if r.get("degraded").and_then(|v| v.as_bool()) == Some(true) {
                    assert_eq!(rp, FAST_GOOD, "{r}");
                    degrade_seen.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    };

    // --- scenario: bursty ----------------------------------------------
    let bursty_trace = loadgen::bursty(21, 4.0 * svc, rps(1.0), rps(5.0), 2.0 * svc, 1);
    let bursty_out = replay_trace(
        addr,
        &bursty_trace,
        |i, _| gen_req(BUCKETS[0], "auto", &format!("bursty {i}"), 1000 + i as u64, steps),
        &backoff,
    );
    for o in &bursty_out {
        audit(&o.resp);
    }
    let bursty_t = tally(&bursty_out);
    assert_eq!(
        bursty_t.other_err, 0,
        "bursty traffic must only see ok/overloaded/deadline answers"
    );
    assert_eq!(
        bursty_t.ok + bursty_t.overloaded,
        bursty_trace.len(),
        "every bursty arrival must end with a definitive answer"
    );

    // --- scenario: flash crowd ------------------------------------------
    let flash_trace =
        loadgen::flash_crowd(22, 5.0 * svc, rps(0.8), 1.0 * svc, 1.0 * svc, rps(10.0), 1);
    let flash_out = replay_trace(
        addr,
        &flash_trace,
        |i, _| gen_req(BUCKETS[0], "auto", &format!("flash {i}"), 2000 + i as u64, steps),
        &backoff,
    );
    for o in &flash_out {
        audit(&o.resp);
    }
    let flash_t = tally(&flash_out);
    assert_eq!(flash_t.other_err, 0);
    assert!(flash_t.ok >= 1, "the flash crowd must serve someone");
    let flash_p99 = stats::percentile(&flash_t.latencies_ok, 99.0);
    // Graceful, not unbounded: with the queue capped at MAX_QUEUE and
    // excess refused at the door, a *served* request's latency is bounded
    // by one queue drain plus retries — far under the whole-spike wait an
    // unbounded queue would impose. The multiplier is generous for CI
    // noise; the property is the *existence* of a load-independent bound.
    assert!(
        flash_p99 <= calm_p99 * 20.0 + 2.0,
        "flash-crowd p99 {flash_p99:.3}s not gracefully bounded \
         (calm p99 {calm_p99:.3}s)"
    );

    // --- scenario: mixed soak -------------------------------------------
    // Two buckets merged (class -> bucket), deadlines sprinkled in: every
    // 5th arrival carries a 1 ms deadline (a guaranteed miss — admitted,
    // then answered by a deadline sweep, never hogging a lane), the rest
    // a generous one. Afterwards the books must close exactly.
    let soak_trace = loadgen::merge(&[
        loadgen::ramp(23, 4.0 * svc, rps(0.5), rps(3.0), 1),
        loadgen::rate_trace(24, "fig22-soak-4s", 4.0 * svc, 1, |_| rps(1.0))
            .into_iter()
            .map(|a| Arrival { at_s: a.at_s, class: 1 })
            .collect(),
    ]);
    let soak_out = replay_trace(
        addr,
        &soak_trace,
        |i, a| {
            let bucket = BUCKETS[a.class.min(1)];
            let policy = if i % 2 == 0 { "auto" } else { TUNED };
            let req = gen_req(bucket, policy, &format!("soak {i}"), 3000 + i as u64, steps);
            if i % 5 == 4 {
                with_deadline(req, 1)
            } else {
                with_deadline(req, 120_000)
            }
        },
        &backoff,
    );
    for o in &soak_out {
        audit(&o.resp);
    }
    let soak_t = tally(&soak_out);
    assert_eq!(soak_t.other_err, 0, "soak saw unexpected errors");
    assert!(
        soak_t.deadline >= soak_trace.len() / 5,
        "every 1 ms deadline must miss: {} misses of {} tight arrivals",
        soak_t.deadline,
        soak_trace.len() / 5
    );

    // --- final accounting ------------------------------------------------
    // The server must be fully drained and its ledgers must close against
    // the client-side tallies of everything this harness ever sent.
    let total_ok = 2 /* calibration */ + calm.len() + 2 /* plugs */ + MAX_QUEUE
        + DEGRADE_AT + 1 /* degrade probe */ + 1 /* pressure-off */
        + bursty_t.ok + flash_t.ok + soak_t.ok;
    let total_deadline = bursty_t.deadline + flash_t.deadline + soak_t.deadline;
    let total_overloaded_final =
        1 /* admission probe */ + bursty_t.overloaded + flash_t.overloaded + soak_t.overloaded;

    let mut c = Client::connect(&addr)?;
    let s = stats_op(&mut c);
    let requests = get_f64(&s, "requests");
    let retires = get_f64(&s, "retires");
    let errors = get_f64(&s, "errors");
    let rejects = get_f64(&s, "rejects");
    let misses = get_f64(&s, "deadline_misses");
    let peak = get_f64(&s, "queue_depth_peak");

    assert_eq!(get_f64(&s, "lanes_active"), 0.0, "stalled sessions: {s}");
    assert_eq!(get_f64(&s, "queue_depth"), 0.0, "stranded queue jobs: {s}");
    assert_eq!(
        requests,
        retires + errors,
        "admitted-request ledger must close: {s}"
    );
    assert_eq!(retires, total_ok as f64, "server retires vs client ok tally: {s}");
    assert_eq!(misses, total_deadline as f64, "deadline ledger vs client tally: {s}");
    assert_eq!(errors, misses, "soak errors must all be deadline misses: {s}");
    assert!(
        rejects >= total_overloaded_final as f64,
        "every overloaded answer is a counted reject (retries add more): {s}"
    );
    assert_eq!(
        peak,
        MAX_QUEUE as f64,
        "bounded admission: the queue was driven exactly to --max-queue \
         and must never exceed it: {s}"
    );
    // Every client-observed degraded response cost at least one resolve
    // swap; the deterministic valve scenario adds one more (retries and
    // rejected-after-resolve attempts can only push the server count up).
    let swaps = get_f64(&s, "degrade_swaps");
    assert!(
        swaps >= degrade_seen.load(Ordering::Relaxed) as f64 + 1.0,
        "degrade_swaps below the client-observed floor: {s}"
    );

    server.shutdown();

    // --- report ----------------------------------------------------------
    let mut report = Report::new(
        "fig22_overload",
        "Figure 22 — SLO-aware overload control: bounded admission, deadlines, degradation",
    );
    report.config("model", Json::str(MODEL));
    report.config(
        "buckets",
        Json::Arr(BUCKETS.iter().map(|b| Json::str(b)).collect()),
    );
    report.config("steps", Json::num(steps as f64));
    report.config("max_batch", Json::num(MAX_BATCH as f64));
    report.config("max_queue", Json::num(MAX_QUEUE as f64));
    report.config("degrade_threshold", Json::num(DEGRADE_AT as f64));
    report.config("tuned_spec", Json::str(TUNED));
    report.config("degrade_spec", Json::str(FAST_GOOD));
    report.config("service_unit_s", Json::num(svc));

    let mut tbl = MdTable::new(&[
        "Scenario",
        "Arrivals",
        "Served",
        "Deadline miss",
        "Refused (final)",
        "p50 lat(s)",
        "p99 lat(s)",
    ]);
    let calm_t = Tally {
        ok: calm.len(),
        deadline: 0,
        overloaded: 0,
        other_err: 0,
        latencies_ok: calm.clone(),
    };
    for (name, n, t) in [
        ("calm", calm.len(), &calm_t),
        ("bursty", bursty_trace.len(), &bursty_t),
        ("flash-crowd", flash_trace.len(), &flash_t),
        ("mixed-soak", soak_trace.len(), &soak_t),
    ] {
        tbl.row(vec![
            name.to_string(),
            format!("{n}"),
            format!("{}", t.ok),
            format!("{}", t.deadline),
            format!("{}", t.overloaded),
            format!("{:.3}", stats::percentile(&t.latencies_ok, 50.0)),
            format!("{:.3}", stats::percentile(&t.latencies_ok, 99.0)),
        ]);
    }
    report.table("Open-loop traces against the live server (retrying clients)", &tbl);
    report.csv("scenarios", &tbl);

    report.metric("calm_p99_s", calm_p99);
    report.metric("flash_p99_s", flash_p99);
    report.metric("queue_depth_peak", peak);
    report.metric("rejects", rejects);
    report.metric("deadline_misses", misses);
    report.metric("degrade_swaps", swaps);
    report.metric("degrade_headroom_s", get_f64(&s, "degrade_headroom_s"));
    report.metric("requests", requests);
    report.metric("retires", retires);

    let auto_served = resolved_log.lock().unwrap().len();
    report.text(&format!(
        "\nThe queue never exceeded --max-queue ({MAX_QUEUE}); the flash-crowd \
         p99 stayed within 20x calm p99 + 2s ({flash_p99:.3}s vs {calm_p99:.3}s); \
         {swaps:.0} degrade swap(s) served only the in-budget tier \
         ({auto_served} auto responses audited, none below the min-PSNR \
         budget); every deadline miss and reject is accounted and the soak \
         drained to zero lanes and zero queued jobs."
    ));
    report.finish()?;
    Ok(())
}
