//! Reproduces **Table 2**: effect of reuse settings (N, R) on OpenSora
//! (240p, 2s, T=60, W=15%, γ=0.5), latency + PSNR compared to PAB.
//!
//! Paper shape to check: larger N/R monotonically lowers latency and PSNR;
//! Foresight beats PAB's PSNR up to N=3 and falls slightly below at N=4.

use foresight::bench_support::{run_suite, BenchCtx};
use foresight::util::benchkit::{MdTable, Report};
use foresight::workload;

fn main() -> anyhow::Result<()> {
    let mut ctx = BenchCtx::new()?;
    let engine = ctx.engine("opensora-sim", "240p-2s")?;
    let steps = Some(60); // paper: T=60 for this ablation
    let prompts = workload::vbench_prompts(1)[..3].to_vec();

    let settings: &[(&str, &str)] = &[
        ("PAB", "pab"),
        ("N=1, R=2", "foresight:n=1,r=2,gamma=0.5,warmup=0.15"),
        ("N=2, R=3", "foresight:n=2,r=3,gamma=0.5,warmup=0.15"),
        ("N=3, R=4", "foresight:n=3,r=4,gamma=0.5,warmup=0.15"),
        ("N=4, R=5", "foresight:n=4,r=5,gamma=0.5,warmup=0.15"),
    ];
    let (_base, rows) = run_suite(&engine, &prompts, settings, steps)?;
    let pab = &rows[0];

    let mut t = MdTable::new(&["Settings", "Latency (s)", "Δ vs PAB", "PSNR", "Δ vs PAB"]);
    for r in &rows {
        t.row(vec![
            r.name.clone(),
            format!("{:.2}", r.latency_mean()),
            format!("{:+.2}", r.latency_mean() - pab.latency_mean()),
            format!("{:.2}", r.psnr),
            if r.psnr.is_nan() || pab.psnr.is_nan() {
                "-".into()
            } else {
                format!("{:+.2}", r.psnr - pab.psnr)
            },
        ]);
    }

    let mut report = Report::new(
        "table2",
        "Table 2 — reuse settings (N, R) on OpenSora-sim (240p, 2s, T=60, W=15%, γ=0.5)",
    );
    report.table("latency/PSNR vs PAB", &t);
    report.csv("series", &t);

    // shape assertions logged for EXPERIMENTS.md
    let lat: Vec<f64> = rows[1..].iter().map(|r| r.latency_mean()).collect();
    let psnr: Vec<f64> = rows[1..].iter().map(|r| r.psnr).collect();
    report.text(&format!(
        "\nshape check: latency monotone decreasing = {}; PSNR monotone decreasing = {}",
        lat.windows(2).all(|w| w[1] <= w[0] * 1.05),
        psnr.windows(2).all(|w| w[1] <= w[0] + 0.5),
    ));
    report.finish()?;
    Ok(())
}
