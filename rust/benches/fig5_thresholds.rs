//! Reproduces **Figure 5**: Foresight's adaptive reuse thresholds λ —
//! (left) spatial-block thresholds for two different prompts at 240p;
//! (right) spatial vs temporal thresholds for the same prompt at 720p.
//!
//! Paper shape: thresholds vary per layer, differ across prompts, and shift
//! when the resolution changes.

use foresight::bench_support::BenchCtx;
use foresight::engine::Request;
use foresight::model::BlockKind;
use foresight::policy::build_policy;
use foresight::util::benchkit::{MdTable, Report};

fn main() -> anyhow::Result<()> {
    let mut ctx = BenchCtx::new()?;
    let mut report = Report::new("fig5", "Figure 5 — adaptive reuse thresholds λ");

    // --- left: two prompts at 240p -----------------------------------------
    let engine = ctx.engine("opensora-sim", "240p-2s")?;
    let info = engine.model().info.clone();
    let prompts = [
        "a still mountain lake mirrors the dawn sky, calm and quiet",
        "a skateboarder jumping and spinning rapidly through a crowded plaza",
    ];
    let mut lambdas = Vec::new();
    for p in prompts {
        let mut pol = build_policy("foresight", &info, info.steps)?;
        let r = engine.generate(&Request::new(p, 3), pol.as_mut(), None)?;
        lambdas.push(r.thresholds.unwrap());
    }
    let mut tl = MdTable::new(&["layer", "λ prompt A (spatial)", "λ prompt B (spatial)"]);
    for l in 0..info.layers {
        tl.row(vec![
            l.to_string(),
            format!("{:.4e}", lambdas[0][&(l, BlockKind::Spatial, 0)]),
            format!("{:.4e}", lambdas[1][&(l, BlockKind::Spatial, 0)]),
        ]);
    }
    report.table("left: spatial λ for two prompts (240p, 2s)", &tl);
    report.csv("prompts_240p", &tl);

    // --- right: spatial vs temporal at 720p ---------------------------------
    let engine = ctx.engine("opensora-sim", "720p-2s")?;
    let mut pol = build_policy("foresight", &info, info.steps)?;
    let r = engine.generate(&Request::new(prompts[0], 3), pol.as_mut(), None)?;
    let th = r.thresholds.unwrap();
    let mut tr = MdTable::new(&["layer", "λ spatial", "λ temporal"]);
    for l in 0..info.layers {
        tr.row(vec![
            l.to_string(),
            format!("{:.4e}", th[&(l, BlockKind::Spatial, 0)]),
            format!("{:.4e}", th[&(l, BlockKind::Temporal, 0)]),
        ]);
    }
    report.table("right: spatial vs temporal λ (720p, 2s)", &tr);
    report.csv("spatial_temporal_720p", &tr);

    report.finish()?;
    Ok(())
}
