//! Reproduces **Table 1**: VBench / PSNR / SSIM / LPIPS / FVD / latency /
//! speedup for {Baseline, Static, Δ-DiT, T-GATE, PAB, Foresight N1R2,
//! Foresight N2R3} across the three evaluation models.
//!
//! Paper protocol: 550 VBench prompts (50 × 11 categories) per model.
//! Default scale runs a stratified subset; `FORESIGHT_BENCH_SCALE=paper`
//! restores the full count. The *shape* to check against the paper:
//! Foresight N1R2 has the best PSNR/SSIM/LPIPS/FVD of all reuse methods,
//! N2R3 the best speedup at near-PAB-or-better quality, Static the worst
//! quality, Δ-DiT/T-GATE minor speedups.

use foresight::bench_support::{run_suite, scaled, BenchCtx, PAPER_MODELS, TABLE1_METHODS};
use foresight::util::benchkit::{MdTable, Report};
use foresight::workload;

fn main() -> anyhow::Result<()> {
    let mut ctx = BenchCtx::new()?;
    let per_category = scaled(50).min(50);
    let prompts = workload::vbench_prompts(per_category.max(1));
    // stratify down to a manageable subset in quick mode (1 per category)
    let take = scaled(550).max(4).min(prompts.len());
    let prompts: Vec<_> = prompts
        .iter()
        .step_by((prompts.len() / take).max(1))
        .cloned()
        .take(take)
        .collect();

    let mut report = Report::new(
        "table1",
        "Table 1 — quality/latency comparison on the VBench prompt set",
    );
    report.text(&format!(
        "{} prompts per model (paper: 550). Metrics vs. no-reuse baseline; \
         LPIPS/FVD/VBench are the documented proxies (DESIGN.md §1).\n",
        prompts.len()
    ));

    for (model, bucket) in PAPER_MODELS {
        let engine = ctx.engine(model, bucket)?;
        let (base, rows) = run_suite(&engine, &prompts, &TABLE1_METHODS, None)?;

        let mut t = MdTable::new(&[
            "Method", "VBench(%)", "PSNR", "SSIM", "LPIPS", "FVD", "Latency(s)", "Speedup",
        ]);
        t.row(vec![
            base.name.clone(),
            format!("{:.2}", base.vbench),
            "-".into(),
            "-".into(),
            "-".into(),
            "-".into(),
            base.latency_cell(),
            "-".into(),
        ]);
        for r in &rows {
            t.row(vec![
                r.name.clone(),
                format!("{:.2}", r.vbench),
                format!("{:.2}", r.psnr),
                format!("{:.3}", r.ssim),
                format!("{:.4}", r.lpips),
                format!("{:.2}", r.fvd),
                r.latency_cell(),
                format!("{:.2}x", r.speedup_vs(&base)),
            ]);
        }
        report.table(&format!("{model} @ {bucket}"), &t);
        report.csv(&format!("{model}"), &t);

        // paper §4.2 memory claim: coarse vs fine cache
        let fs = rows.iter().find(|r| r.name.contains("N1R2")).unwrap();
        let pab = rows.iter().find(|r| r.name == "PAB").unwrap();
        report.text(&format!(
            "cache peak: Foresight {:.0} KiB (2LHWF) vs PAB {:.0} KiB (6LHWF fine-grained)\n",
            fs.cache_peak_bytes as f64 / 1024.0,
            pab.cache_peak_bytes as f64 / 1024.0
        ));
    }
    report.finish()?;
    Ok(())
}
