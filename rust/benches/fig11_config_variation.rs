//! Reproduces **Figure 11** (appendix): quantitative feature variation of
//! the last Spatial-DiT layer across prompts, seeds, resolutions, durations
//! and denoising-step counts — one knob varied at a time.
//!
//! Paper shape: every knob visibly moves the mean consecutive-step MSE, so
//! an adaptive policy must re-derive its thresholds per configuration.

use foresight::analysis::DynamicsRecorder;
use foresight::bench_support::BenchCtx;
use foresight::engine::Request;
use foresight::model::BlockKind;
use foresight::policy::build_policy;
use foresight::util::benchkit::{MdTable, Report};

const BASE_PROMPT: &str =
    "a narrow cobblestone alley in gentle rain, a black cat darts across, \
     lamps glowing softly";

fn main() -> anyhow::Result<()> {
    let mut ctx = BenchCtx::new()?;
    let mut report = Report::new(
        "fig11",
        "Figure 11 — feature variation across generation configurations (analysis preset)",
    );
    let mut t = MdTable::new(&["axis", "setting", "mean MSE (last spatial layer)"]);

    let mut probe = |ctx: &mut BenchCtx,
                     bucket: &str,
                     prompt: &str,
                     seed: u64,
                     steps: Option<usize>|
     -> anyhow::Result<f64> {
        let engine = ctx.engine("analysis", bucket)?;
        let info = engine.model().info.clone();
        let mut rec = DynamicsRecorder::new();
        let mut pol = build_policy("none", &info, steps.unwrap_or(info.steps))?;
        let mut req = Request::new(prompt, seed);
        req.steps = steps;
        engine.generate(&req, pol.as_mut(), Some(&mut rec))?;
        Ok(rec.mean_step_mse(info.layers - 1, BlockKind::Spatial))
    };

    // prompts
    for (label, p) in [
        ("calm", "a tranquil zen garden, still stones, soft light"),
        ("base", BASE_PROMPT),
        ("dynamic", "a storm chase: cars racing and crashing, waves exploding"),
    ] {
        let m = probe(&mut ctx, "240p-2s", p, 1, None)?;
        t.row(vec!["prompt".into(), label.into(), format!("{m:.4e}")]);
    }
    // seeds
    for seed in [1u64, 2, 3] {
        let m = probe(&mut ctx, "240p-2s", BASE_PROMPT, seed, None)?;
        t.row(vec!["seed".into(), seed.to_string(), format!("{m:.4e}")]);
    }
    // resolutions
    for bucket in ["240p-2s", "480p-2s", "720p-2s"] {
        let m = probe(&mut ctx, bucket, BASE_PROMPT, 1, None)?;
        t.row(vec!["resolution".into(), bucket.into(), format!("{m:.4e}")]);
    }
    // duration (240p 2s vs 4s — only exported for opensora; use steps instead
    // for the analysis preset, plus the opensora 4s bucket via its own model)
    for steps in [15usize, 30, 60] {
        let m = probe(&mut ctx, "240p-2s", BASE_PROMPT, 1, Some(steps))?;
        t.row(vec!["denoising steps".into(), steps.to_string(), format!("{m:.4e}")]);
    }

    report.table("one-knob-at-a-time variation", &t);
    report.csv("series", &t);
    report.finish()?;
    Ok(())
}
