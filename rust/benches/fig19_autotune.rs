//! **Figure 19 (repo-original)**: profile-guided autotuning.
//!
//! Profiles a small policy grid on one (model, bucket, steps) key —
//! Foresight (γ, warmup) points beside the static baseline and the fixed
//! serving default — and asserts the autotune contract:
//!
//! * the tuned selection **Pareto-dominates or matches** the fixed default
//!   on the same sweep measurements: when the default meets the quality
//!   budget, the tuned config is at least as fast and also inside the
//!   budget; when the default misses the budget, the tuned config has at
//!   least the default's quality — either way `policy=auto` never serves
//!   something strictly worse than today's hardcoded spec;
//! * the chosen spec round-trips through `build_policy` (the serving path
//!   parses exactly what the profiler emitted);
//! * the persisted `ProfileStore` round-trips: save → load → the exact
//!   lookup returns the identical spec and profile version.
//!
//! `FORESIGHT_BENCH_STEPS` overrides the step count (CI smoke mode runs a
//! reduced schedule). Exits cleanly with a SKIP note when the AOT
//! artifacts are absent (e.g. hosted CI).

use foresight::autotune::{
    pareto_frontier, profile_engine, sweep_table, GridSpec, ProfileOptions, ProfileStore,
    DEFAULT_KNOBS,
};
use foresight::bench_support::BenchCtx;
use foresight::policy::build_policy;
use foresight::util::benchkit::Report;

const MODEL: (&str, &str) = ("opensora-sim", "240p-2s");
const MIN_PSNR: f64 = 25.0;

fn bench_steps() -> usize {
    std::env::var("FORESIGHT_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10)
        .max(2)
}

fn main() -> anyhow::Result<()> {
    let mut ctx = match BenchCtx::new() {
        Ok(c) => c,
        Err(e) => {
            println!("[fig19] SKIP: artifacts unavailable ({e:#}); run `make artifacts`");
            return Ok(());
        }
    };
    let steps = bench_steps();
    let engine = ctx.engine(MODEL.0, MODEL.1)?;
    let info = engine.model().info.clone();

    let opts = ProfileOptions {
        steps: Some(steps),
        prompts: 2,
        min_psnr: MIN_PSNR,
        grid: GridSpec {
            nr: vec![(1, 2)],
            gammas: vec![0.25, 1.0, 2.0],
            warmups: vec![0.15],
            static_nr: vec![(1, 2)],
            orders: vec![1, 2],
        },
    };
    let outcome = profile_engine(&engine, &opts)?;
    let profile = &outcome.profile;
    let points = &outcome.points;

    let mut report = Report::new(
        "fig19",
        "Figure 19 — profile-guided autotune: tuned config vs the fixed default",
    );
    let t = sweep_table(&outcome);
    report.table(
        &format!("sweep at {} (budget PSNR >= {MIN_PSNR} dB)", profile.key),
        &t,
    );
    report.csv("series", &t);

    // --- acceptance: the sweep includes the fixed serving default and the
    // frontier is well-formed.
    let default_spec = DEFAULT_KNOBS.spec();
    let default_pt = points
        .iter()
        .find(|p| p.spec == default_spec)
        .expect("sweep always includes the serving default");
    let chosen = points
        .iter()
        .find(|p| p.spec == profile.spec)
        .expect("chosen spec is a sweep point");
    assert!(!profile.frontier.is_empty(), "empty Pareto frontier");
    assert_eq!(
        pareto_frontier(points),
        profile.frontier,
        "stored frontier must be the frontier of the sweep"
    );

    // --- acceptance: tuned Pareto-dominates or matches the fixed default
    // on the same measurements.
    if default_pt.psnr >= MIN_PSNR {
        assert!(
            chosen.psnr >= MIN_PSNR,
            "tuned config broke the quality budget: {:.2} < {MIN_PSNR}",
            chosen.psnr
        );
        assert!(
            chosen.wall_s <= default_pt.wall_s,
            "tuned config ({}, {:.3}s) slower than the fixed default ({:.3}s)",
            chosen.spec,
            chosen.wall_s,
            default_pt.wall_s
        );
    } else {
        assert!(
            chosen.psnr >= default_pt.psnr,
            "default misses the budget, so the tuned config must be at least \
             as good: {:.2} vs {:.2}",
            chosen.psnr,
            default_pt.psnr
        );
    }

    // --- acceptance: the chosen spec is servable (round-trips the parser).
    build_policy(&profile.spec, &info, steps).expect("chosen spec must parse");

    // --- acceptance: persisted store round-trips to identical lookups.
    let path = std::path::Path::new("results").join("fig19_profiles.json");
    let mut store = ProfileStore::new();
    store.insert(outcome.profile.clone());
    store.save(&path)?;
    let loaded = ProfileStore::load(&path)?;
    let looked = loaded
        .lookup(MODEL.0, MODEL.1, info.sampler.name(), steps)
        .expect("saved profile must be found");
    assert_eq!(looked.kind(), "exact");
    assert_eq!(looked.profile().spec, profile.spec);
    assert_eq!(looked.profile().profile_version, 1);

    report.text(&format!(
        "\nTuned: `{}` at {:.3}s / PSNR {:.2} dB vs default `{}` at {:.3}s / \
         PSNR {:.2} dB ({} sweep points, {} on the frontier). Store saved to \
         {} and verified via load + exact lookup.",
        chosen.spec,
        chosen.wall_s,
        chosen.psnr,
        default_spec,
        default_pt.wall_s,
        default_pt.psnr,
        points.len(),
        profile.frontier.len(),
        path.display()
    ));
    report.finish()?;
    Ok(())
}
