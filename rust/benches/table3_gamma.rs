//! Reproduces **Table 3**: effect of the scaling factor γ on OpenSora-sim
//! (N=1, R=2, 240p, 2s, T=60, W=15%), latency + PSNR compared to PAB.
//!
//! Paper shape: smaller γ → higher latency and higher PSNR (γ=0.25 tops
//! PSNR at a small latency premium); larger γ trades quality for speed.

use foresight::bench_support::{run_suite, BenchCtx};
use foresight::util::benchkit::{MdTable, Report};
use foresight::workload;

fn main() -> anyhow::Result<()> {
    let mut ctx = BenchCtx::new()?;
    let engine = ctx.engine("opensora-sim", "240p-2s")?;
    let steps = Some(60);
    let prompts = workload::vbench_prompts(1)[..3].to_vec();

    let settings: &[(&str, &str)] = &[
        ("PAB", "pab"),
        ("γ=0.25", "foresight:n=1,r=2,gamma=0.25,warmup=0.15"),
        ("γ=0.5", "foresight:n=1,r=2,gamma=0.5,warmup=0.15"),
        ("γ=1.0", "foresight:n=1,r=2,gamma=1.0,warmup=0.15"),
        ("γ=2.0", "foresight:n=1,r=2,gamma=2.0,warmup=0.15"),
    ];
    let (_base, rows) = run_suite(&engine, &prompts, settings, steps)?;
    let pab = &rows[0];

    let mut t = MdTable::new(&["γ", "Latency (s)", "Δ vs PAB", "PSNR", "Δ vs PAB", "Reuse %"]);
    for r in &rows {
        t.row(vec![
            r.name.clone(),
            format!("{:.2}", r.latency_mean()),
            format!("{:+.2}", r.latency_mean() - pab.latency_mean()),
            format!("{:.2}", r.psnr),
            format!("{:+.2}", r.psnr - pab.psnr),
            format!("{:.0}", 100.0 * r.reuse_frac),
        ]);
    }

    let mut report = Report::new(
        "table3",
        "Table 3 — scaling factor γ on OpenSora-sim (N=1, R=2, 240p, 2s, T=60, W=15%)",
    );
    report.table("latency/PSNR vs PAB", &t);
    report.csv("series", &t);

    let psnr: Vec<f64> = rows[1..].iter().map(|r| r.psnr).collect();
    let reuse: Vec<f64> = rows[1..].iter().map(|r| r.reuse_frac).collect();
    report.text(&format!(
        "\nshape check: PSNR decreasing in γ = {}; reuse increasing in γ = {}",
        psnr.windows(2).all(|w| w[1] <= w[0] + 0.5),
        reuse.windows(2).all(|w| w[1] >= w[0] - 0.02),
    ));
    report.finish()?;
    Ok(())
}
