//! Reproduces **Figure 1**: the headline speedup + quality comparison of
//! Foresight vs prior static techniques on all three models (the paper's
//! teaser numbers: up to 1.63× end-to-end with quality preserved).

use foresight::bench_support::{run_suite, BenchCtx, PAPER_MODELS};
use foresight::util::benchkit::{MdTable, Report};
use foresight::workload;

fn main() -> anyhow::Result<()> {
    let mut ctx = BenchCtx::new()?;
    let prompts = workload::vbench_prompts(1)[..3].to_vec();
    let methods: &[(&str, &str)] = &[
        ("Static", "static"),
        ("PAB", "pab"),
        ("Foresight (N2R3)", "foresight:n=2,r=3"),
    ];

    let mut report = Report::new(
        "fig1",
        "Figure 1 — headline: inference time and quality across models",
    );
    let mut t = MdTable::new(&["Model", "Method", "Latency(s)", "Speedup", "PSNR vs base"]);
    let mut best_speedup: f64 = 0.0;

    for (model, bucket) in PAPER_MODELS {
        let engine = ctx.engine(model, bucket)?;
        let (base, rows) = run_suite(&engine, &prompts, methods, None)?;
        t.row(vec![
            model.into(),
            "Baseline".into(),
            base.latency_cell(),
            "1.00x".into(),
            "-".into(),
        ]);
        for r in &rows {
            let sp = r.speedup_vs(&base);
            best_speedup = best_speedup.max(if r.name.contains("Foresight") { sp } else { 0.0 });
            t.row(vec![
                model.into(),
                r.name.clone(),
                r.latency_cell(),
                format!("{sp:.2}x"),
                format!("{:.2}", r.psnr),
            ]);
        }
    }
    report.table("headline comparison", &t);
    report.csv("series", &t);
    report.text(&format!(
        "\nbest Foresight end-to-end speedup observed: {best_speedup:.2}x \
         (paper headline: up to 1.63x on CogVideoX)"
    ));
    report.finish()?;
    Ok(())
}
