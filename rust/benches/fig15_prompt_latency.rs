//! Reproduces **Figure 15** (appendix): per-prompt latency across the
//! prompt set — Baseline and Static are flat (fixed schedules) while
//! Foresight's latency varies with prompt complexity (dynamic reuse).

use foresight::bench_support::{run_one, scaled, BenchCtx};
use foresight::util::benchkit::{MdTable, Report};
use foresight::util::stats;
use foresight::workload;

fn main() -> anyhow::Result<()> {
    let mut ctx = BenchCtx::new()?;
    let engine = ctx.engine("opensora-sim", "240p-2s")?;
    let mut prompts = workload::vbench_prompts(1);
    prompts.truncate(scaled(50).clamp(4, 8).max(4));
    let _ = run_one(&engine, "none", "warmup", 0, Some(2))?;

    let mut rows: Vec<(String, f64, f64, f64, f64)> = Vec::new();
    for p in &prompts {
        let base = run_one(&engine, "none", &p.text, p.id as u64, None)?;
        let stat = run_one(&engine, "static", &p.text, p.id as u64, None)?;
        let fs = run_one(&engine, "foresight", &p.text, p.id as u64, None)?;
        rows.push((
            p.text.chars().take(36).collect(),
            workload::motion_complexity(&p.text),
            base.stats.wall_s,
            stat.stats.wall_s,
            fs.stats.wall_s,
        ));
    }
    // sort ascending by foresight latency (the paper sorts by latency)
    rows.sort_by(|a, b| a.4.total_cmp(&b.4));

    let mut t = MdTable::new(&[
        "prompt", "motion", "baseline (s)", "static (s)", "foresight (s)",
    ]);
    for (p, m, b, s, f) in &rows {
        t.row(vec![
            p.clone(),
            format!("{m:.2}"),
            format!("{b:.2}"),
            format!("{s:.2}"),
            format!("{f:.2}"),
        ]);
    }

    let mut report = Report::new(
        "fig15",
        "Figure 15 — per-prompt latency (opensora-sim 240p-2s), sorted by Foresight latency",
    );
    report.table("per-prompt latencies", &t);
    report.csv("series", &t);

    let cv = |xs: &[f64]| stats::std(xs) / stats::mean(xs).max(1e-12);
    let base_cv = cv(&rows.iter().map(|r| r.2).collect::<Vec<_>>());
    let stat_cv = cv(&rows.iter().map(|r| r.3).collect::<Vec<_>>());
    let fs_cv = cv(&rows.iter().map(|r| r.4).collect::<Vec<_>>());
    report.text(&format!(
        "\nlatency coefficient of variation: baseline {base_cv:.3}, static {stat_cv:.3}, \
         foresight {fs_cv:.3} (paper: only Foresight adapts latency to the prompt)"
    ));
    report.finish()?;
    Ok(())
}
