//! Reproduces **Figure 7**: varying the warmup fraction W (5%..40%) with
//! fixed N=1, R=2, γ=0.5 on OpenSora-sim.
//!
//! Paper shape: more warmup → fewer reuse-eligible steps → higher quality
//! (PSNR toward baseline) but smaller speedup.

use foresight::bench_support::{run_suite, BenchCtx};
use foresight::util::benchkit::{MdTable, Report};
use foresight::workload;

fn main() -> anyhow::Result<()> {
    let mut ctx = BenchCtx::new()?;
    let engine = ctx.engine("opensora-sim", "240p-2s")?;
    let prompts = workload::vbench_prompts(1)[..3].to_vec();

    let settings: Vec<(String, String)> = [5, 10, 15, 20, 25, 30, 40]
        .into_iter()
        .map(|w| {
            (
                format!("W={w}%"),
                format!("foresight:n=1,r=2,gamma=0.5,warmup=0.{w:02}"),
            )
        })
        .collect();
    let specs: Vec<(&str, &str)> =
        settings.iter().map(|(a, b)| (a.as_str(), b.as_str())).collect();

    let (base, rows) = run_suite(&engine, &prompts, &specs, None)?;

    let mut t = MdTable::new(&["Warmup", "Latency(s)", "Speedup", "Reuse %", "PSNR"]);
    for r in &rows {
        t.row(vec![
            r.name.clone(),
            format!("{:.2}", r.latency_mean()),
            format!("{:.2}x", r.speedup_vs(&base)),
            format!("{:.0}", 100.0 * r.reuse_frac),
            format!("{:.2}", r.psnr),
        ]);
    }

    let mut report = Report::new(
        "fig7",
        "Figure 7 — warmup fraction sweep (N=1, R=2, γ=0.5, opensora-sim 240p-2s)",
    );
    report.table("warmup sweep", &t);
    report.csv("series", &t);
    let psnr: Vec<f64> = rows.iter().map(|r| r.psnr).collect();
    let reuse: Vec<f64> = rows.iter().map(|r| r.reuse_frac).collect();
    report.text(&format!(
        "\nshape check: PSNR non-decreasing in W = {}; reuse non-increasing in W = {}",
        psnr.windows(2).all(|w| w[1] >= w[0] - 0.5),
        reuse.windows(2).all(|w| w[1] <= w[0] + 0.02),
    ));
    report.finish()?;
    Ok(())
}
