//! **Figure 24 (repo-original)**: feature forecasting vs verbatim replay
//! on reuse steps.
//!
//! Same reuse schedule, two ways to serve a reuse step: replay the stale
//! cached output verbatim, or extrapolate the site's next output from its
//! history ring in one fused `lms_combine` dispatch (policy
//! `forecast:k=...,inner=...`). Asserts the forecasting win conditions:
//!
//! * **equal-schedule quality** — at identical reuse fraction, order-2
//!   forecasting strictly improves mean PSNR over verbatim replay;
//! * **tuned speed** — under the same min-PSNR budget, budgeted selection
//!   ([`foresight::autotune::select`]) over forecast candidates picks a
//!   strictly faster configuration than over replay-only candidates;
//! * **zero reuse-step traffic** — a forecast run moves exactly the bytes
//!   of its replay twin plus the `k` admit-time rank-0 coefficient
//!   uploads (4 B each): the reuse steps themselves transfer nothing;
//! * **k=1 identity** — `forecast:k=1,inner=X` is bit-identical to `X`
//!   (latents and counters), with no coefficient uploads;
//! * **exact fallback accounting** — `forecast_units` /
//!   `forecast_fallback_units` match a host-side oracle replayed from the
//!   decision map: history-starved sites replay verbatim, per site.
//!
//! `FORESIGHT_BENCH_STEPS` overrides the step count, clamped to >= 8 so
//! history rings actually fill and forecasts fire. Exits cleanly with a
//! SKIP note when the AOT artifacts are absent (e.g. hosted CI).

use foresight::autotune::{select, spec_order, ProfilePoint};
use foresight::bench_support::{run_one, BenchCtx};
use foresight::engine::{RunResult, StepDecision};
use foresight::metrics::{self, Decoder};
use foresight::util::benchkit::{MdTable, Report};
use foresight::util::stats::Welford;

const MODEL: (&str, &str) = ("opensora-sim", "240p-2s");
/// The equal-schedule inner: compute every 2nd step (50% reuse).
const INNER: &str = "static:n=1,r=2";
/// The aggressive schedule for the tuned-selection contest (75% reuse).
const AGGR: &str = "static:n=1,r=4";

fn bench_steps() -> usize {
    std::env::var("FORESIGHT_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(12)
        .max(8)
}

fn panel() -> Vec<(&'static str, u64)> {
    vec![
        ("a calm lake at dawn, soft golden light", 11),
        ("a crowded night market, neon signs flickering in rain", 23),
    ]
}

/// Host-side oracle for the forecast counters of one branch: replay the
/// per-step decision map, tracking how many outputs each site has stored,
/// and classify every planned Predict as served (history >= k) or
/// starved (fell back to verbatim replay).
fn forecast_oracle(map: &[Vec<StepDecision>], k: usize) -> (u64, u64) {
    let sites = map.first().map_or(0, |s| s.len());
    let (mut served, mut starved) = (0u64, 0u64);
    for site in 0..sites {
        let mut stored = 0usize;
        for step in map {
            match step[site] {
                StepDecision::Compute => stored += 1,
                StepDecision::Predict if stored >= k => served += 1,
                StepDecision::Predict if stored >= 1 => starved += 1,
                // cold-cache Predict: the engine computes (and stores)
                StepDecision::Predict => stored += 1,
                StepDecision::Reuse => {}
            }
        }
    }
    (served, starved)
}

fn main() -> anyhow::Result<()> {
    let mut ctx = match BenchCtx::new() {
        Ok(c) => c,
        Err(e) => {
            println!("[fig24] SKIP: artifacts unavailable ({e:#}); run `make artifacts`");
            return Ok(());
        }
    };
    let steps = bench_steps();
    let engine = ctx.engine(MODEL.0, MODEL.1)?;
    let dec = {
        let b = &engine.model().bucket;
        Decoder::new(b.ph, b.pw, engine.model().info.latent_channels)
    };

    let prompts = panel();
    let mut base_wall = Welford::new();
    let mut base_frames = Vec::new();
    for (text, seed) in &prompts {
        let r = run_one(&engine, "none", text, *seed, Some(steps))?;
        base_wall.push(r.stats.wall_s);
        base_frames.push(dec.decode(&r.latents));
    }

    // (runs, mean wall, mean PSNR vs baseline, mean reuse fraction)
    let measure = |spec: &str| -> anyhow::Result<(Vec<RunResult>, f64, f64, f64)> {
        let mut wall = Welford::new();
        let mut psnr = Welford::new();
        let mut reuse = Welford::new();
        let mut runs = Vec::new();
        for (i, (text, seed)) in prompts.iter().enumerate() {
            let r = run_one(&engine, spec, text, *seed, Some(steps))?;
            wall.push(r.stats.wall_s);
            reuse.push(r.stats.reuse_fraction());
            psnr.push(metrics::psnr(&base_frames[i], &dec.decode(&r.latents)));
            runs.push(r);
        }
        Ok((runs, wall.mean(), psnr.mean(), reuse.mean()))
    };

    let k1_spec = format!("forecast:k=1,inner={INNER}");
    let k2_spec = format!("forecast:k=2,inner={INNER}");
    let k3_spec = format!("forecast:k=3,inner={INNER}");
    let fc_aggr_spec = format!("forecast:k=2,inner={AGGR}");

    let (rp_runs, rp_wall, rp_psnr, rp_reuse) = measure(INNER)?;
    let (k1_runs, k1_wall, k1_psnr, _) = measure(&k1_spec)?;
    let (k2_runs, k2_wall, k2_psnr, k2_reuse) = measure(&k2_spec)?;
    let (k3_runs, k3_wall, k3_psnr, _) = measure(&k3_spec)?;
    let (_rp4_runs, rp4_wall, rp4_psnr, rp4_reuse) = measure(AGGR)?;
    let (_fc4_runs, fc4_wall, fc4_psnr, fc4_reuse) = measure(&fc_aggr_spec)?;

    // --- acceptance: k=1 is the degenerate predictor — bit-identical to
    // its inner, zero forecast counters, zero coefficient uploads.
    for (a, b) in rp_runs.iter().zip(&k1_runs) {
        assert_eq!(
            a.latents.data, b.latents.data,
            "forecast:k=1 must be bit-identical to its inner"
        );
        assert_eq!(b.stats.forecast_units, 0, "k=1 never forecasts");
        assert_eq!(b.stats.forecast_fallback_units, 0, "k=1 never plans a forecast");
        assert_eq!(a.stats.reused_units, b.stats.reused_units);
        assert_eq!(a.stats.h2d_bytes, b.stats.h2d_bytes, "k=1 uploads no coefficients");
        assert_eq!(a.stats.d2h_bytes, b.stats.d2h_bytes);
    }

    // --- acceptance: equal reuse fraction, strictly better PSNR at k=2.
    assert_eq!(
        rp_reuse, k2_reuse,
        "the forecast wrapper must not change the inner reuse schedule"
    );
    assert_eq!(rp4_reuse, fc4_reuse);
    assert!(
        k2_psnr > rp_psnr,
        "order-2 forecasting must beat verbatim replay at equal reuse \
         fraction: {k2_psnr:.2} dB vs {rp_psnr:.2} dB"
    );

    // --- acceptance: a reuse step under forecasting moves zero extra
    // bytes — the whole transfer delta is the admit-time coefficient
    // upload (k rank-0 f32 scalars, 4 B + 1 call each).
    for (k, runs) in [(2u64, &k2_runs), (3, &k3_runs)] {
        for (a, b) in rp_runs.iter().zip(*runs) {
            assert_eq!(
                b.stats.h2d_bytes,
                a.stats.h2d_bytes + 4 * k,
                "k={k}: h2d delta must be exactly the admit-time coefficients"
            );
            assert_eq!(b.stats.h2d_calls, a.stats.h2d_calls + k);
            assert_eq!(
                b.stats.d2h_bytes, a.stats.d2h_bytes,
                "k={k}: forecasting must not download anything extra"
            );
        }
    }

    // --- acceptance: exact per-site fallback accounting. The decision map
    // records one branch's plan; the counters sum every CFG branch, so the
    // oracle scales by the (integral) branch multiplier.
    for (k, runs) in [(2usize, &k2_runs), (3, &k3_runs)] {
        for r in runs.iter() {
            let (served, starved) = forecast_oracle(&r.reuse_map, k);
            let per_branch =
                r.reuse_map.iter().flatten().filter(|d| d.is_reuse()).count() as u64;
            assert!(per_branch > 0, "schedule must contain reuse steps");
            assert_eq!(
                r.stats.reused_units % per_branch,
                0,
                "reused units must be an integral branch multiple"
            );
            let branches = r.stats.reused_units / per_branch;
            assert_eq!(
                r.stats.forecast_units,
                served * branches,
                "k={k}: forecast_units must match the decision-map oracle"
            );
            assert_eq!(
                r.stats.forecast_fallback_units,
                starved * branches,
                "k={k}: forecast_fallbacks must match the history-starvation oracle"
            );
            assert_eq!(
                r.stats.forecast_units + r.stats.forecast_fallback_units,
                r.stats.reused_units,
                "k={k}: every planned reuse is either forecast or falls back"
            );
        }
    }

    // --- acceptance: tuned forecast beats tuned replay at the same
    // min-PSNR budget. The budget splits the aggressive-schedule pair, so
    // it is meetable by forecasting at 75% reuse but not by replaying at
    // 75% reuse — replay must retreat to a slower schedule.
    assert!(
        fc4_psnr > rp4_psnr,
        "forecasting must beat replay at the aggressive schedule too: \
         {fc4_psnr:.2} dB vs {rp4_psnr:.2} dB"
    );
    let budget = 0.5 * (fc4_psnr + rp4_psnr);
    let pt = |spec: &str, wall: f64, reuse: f64, psnr: f64| ProfilePoint {
        spec: spec.into(),
        wall_s: wall,
        reuse_fraction: reuse,
        psnr,
        ssim: 0.0,
        lpips: 0.0,
    };
    let base_pt = pt("none", base_wall.mean(), 0.0, 100.0);
    let replay_points = vec![
        base_pt.clone(),
        pt(INNER, rp_wall, rp_reuse, rp_psnr),
        pt(AGGR, rp4_wall, rp4_reuse, rp4_psnr),
    ];
    let forecast_points = vec![
        base_pt,
        pt(&k2_spec, k2_wall, k2_reuse, k2_psnr),
        pt(&fc_aggr_spec, fc4_wall, fc4_reuse, fc4_psnr),
    ];
    let tuned_rp = select(&replay_points, budget).expect("baseline always in budget").clone();
    let tuned_fc = select(&forecast_points, budget).expect("baseline always in budget").clone();
    assert!(
        tuned_fc.wall_s < tuned_rp.wall_s,
        "at PSNR >= {budget:.2} dB the tuned forecast ({}, {:.3}s) must be \
         strictly faster than the tuned replay ({}, {:.3}s)",
        tuned_fc.spec,
        tuned_fc.wall_s,
        tuned_rp.spec,
        tuned_rp.wall_s
    );

    // --- report ------------------------------------------------------------
    let mut report = Report::new(
        "fig24_forecast",
        "Figure 24 — feature forecasting vs verbatim replay on reuse steps",
    );
    let fsum = |runs: &[RunResult]| {
        runs.iter().map(|r| r.stats.forecast_units).sum::<u64>()
    };
    let fbsum = |runs: &[RunResult]| {
        runs.iter().map(|r| r.stats.forecast_fallback_units).sum::<u64>()
    };
    let mut t = MdTable::new(&[
        "spec", "order", "reuse", "wall(s)", "PSNR", "forecasts", "fallbacks",
    ]);
    for (spec, wall, psnr, reuse, fc, fb) in [
        ("none", base_wall.mean(), 100.0, 0.0, 0, 0),
        (INNER, rp_wall, rp_psnr, rp_reuse, fsum(&rp_runs), fbsum(&rp_runs)),
        (k1_spec.as_str(), k1_wall, k1_psnr, rp_reuse, fsum(&k1_runs), fbsum(&k1_runs)),
        (k2_spec.as_str(), k2_wall, k2_psnr, k2_reuse, fsum(&k2_runs), fbsum(&k2_runs)),
        (k3_spec.as_str(), k3_wall, k3_psnr, k2_reuse, fsum(&k3_runs), fbsum(&k3_runs)),
        (AGGR, rp4_wall, rp4_psnr, rp4_reuse, 0, 0),
        (fc_aggr_spec.as_str(), fc4_wall, fc4_psnr, fc4_reuse, 0, 0),
    ] {
        t.row(vec![
            spec.to_string(),
            spec_order(spec).to_string(),
            format!("{:.0}%", 100.0 * reuse),
            format!("{wall:.3}"),
            format!("{psnr:.2}"),
            fc.to_string(),
            fb.to_string(),
        ]);
    }
    report.table(&format!("forecast vs replay at {steps} steps ({MODEL:?})"), &t);
    report.csv("series", &t);
    report.metric("psnr_replay_db", rp_psnr);
    report.metric("psnr_forecast_k2_db", k2_psnr);
    report.metric("psnr_forecast_k3_db", k3_psnr);
    report.metric("budget_psnr_db", budget);
    report.metric("tuned_replay_wall_s", tuned_rp.wall_s);
    report.metric("tuned_forecast_wall_s", tuned_fc.wall_s);
    report.text(&format!(
        "\nAt equal reuse fraction ({:.0}%), order-2 forecasting improves PSNR \
         {rp_psnr:.2} -> {k2_psnr:.2} dB over verbatim replay; at the shared \
         budget of {budget:.2} dB the tuned forecast (`{}`, {:.3}s) beats the \
         tuned replay (`{}`, {:.3}s). `forecast:k=1` verified bit-identical \
         to its inner; fallback counters verified against the decision-map \
         oracle; forecast reuse steps verified transfer-free.",
        100.0 * rp_reuse,
        tuned_fc.spec,
        tuned_fc.wall_s,
        tuned_rp.spec,
        tuned_rp.wall_s
    ));
    report.finish()?;
    Ok(())
}
