//! Reproduces **Figure 10** (appendix): compute vs memory throughput for
//! Spatial and Temporal attention blocks across resolutions / durations.
//!
//! The paper measures A100 counters; here each block's analytical FLOP and
//! byte counts (model/mod.rs) are combined with measured dispatch times to
//! report achieved FLOP/s, bandwidth and arithmetic intensity, classifying
//! each configuration as compute- or memory-bound relative to the host's
//! measured peak (estimated from the largest observed throughput).
//!
//! Paper shape: spatial attention's intensity grows with resolution
//! (compute-bound); temporal attention stays low-intensity (memory-bound).

use foresight::bench_support::{run_one, BenchCtx};
use foresight::model::BlockKind;
use foresight::util::benchkit::{MdTable, Report};

fn main() -> anyhow::Result<()> {
    let mut ctx = BenchCtx::new()?;
    let mut report = Report::new(
        "fig10",
        "Figure 10 — compute vs memory characterisation of attention blocks",
    );

    let mut t = MdTable::new(&[
        "config", "kind", "GFLOP/dispatch", "MB/dispatch", "intensity (FLOP/B)",
        "time/dispatch (ms)", "GFLOP/s",
    ]);

    let mut rows: Vec<(String, BlockKind, f64, f64, f64, f64)> = Vec::new();
    // spatial: resolution sweep at fixed 2s; temporal: duration sweep at 240p
    for (bucket, kinds) in [
        ("240p-2s", vec![BlockKind::Spatial, BlockKind::Temporal]),
        ("480p-2s", vec![BlockKind::Spatial]),
        ("720p-2s", vec![BlockKind::Spatial]),
        ("240p-4s", vec![BlockKind::Temporal]),
    ] {
        let engine = ctx.engine("opensora-sim", bucket)?;
        let m = engine.model();
        m.reset_op_stats();
        let _ = run_one(&engine, "none", "roofline probe prompt", 2, None)?;
        let stats = m.op_stats();
        for kind in kinds {
            let name = format!("{}_block", kind.name());
            let (calls, secs) = stats
                .iter()
                .find(|(n, _, _)| *n == name)
                .map(|(_, c, s)| (*c, *s))
                .unwrap_or((0, 0.0));
            if calls == 0 {
                continue;
            }
            let per_call = secs / calls as f64;
            let flops = m.block_flops(kind);
            let bytes = m.block_bytes(kind);
            rows.push((bucket.to_string(), kind, flops, bytes, per_call, flops / per_call));
        }
    }
    for (bucket, kind, flops, bytes, per_call, thr) in &rows {
        t.row(vec![
            bucket.clone(),
            kind.name().into(),
            format!("{:.3}", flops / 1e9),
            format!("{:.2}", bytes / 1e6),
            format!("{:.1}", flops / bytes),
            format!("{:.3}", per_call * 1e3),
            format!("{:.2}", thr / 1e9),
        ]);
    }
    report.table("attention block characterisation", &t);
    report.csv("series", &t);

    // classification vs best observed throughput
    let peak = rows.iter().map(|r| r.5).fold(0.0f64, f64::max);
    let mut tc = MdTable::new(&["config", "kind", "% of peak compute", "bound"]);
    for (bucket, kind, _f, _b, _p, thr) in &rows {
        let frac = thr / peak;
        tc.row(vec![
            bucket.clone(),
            kind.name().into(),
            format!("{:.0}", 100.0 * frac),
            if frac > 0.5 { "compute-leaning".into() } else { "memory/overhead-leaning".to_string() },
        ]);
    }
    report.table("bound classification (relative to observed peak)", &tc);
    report.finish()?;
    Ok(())
}
