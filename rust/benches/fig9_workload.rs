//! Reproduces **Figure 9** (appendix): workload characterisation —
//! (left) end-to-end latency across resolutions; (right) inference-time
//! breakdown by operator type (attention vs FFN vs non-linear glue).
//!
//! Paper shape: latency grows super-linearly with resolution (quadratic
//! attention); attention dominates the breakdown, with a sizable share for
//! the non-attention glue the fused kernels target.

use foresight::bench_support::{run_one, BenchCtx};
use foresight::cache::Unit;
use foresight::engine::Request;
use foresight::policy::{Action, CacheMode, Granularity, ReusePolicy, Site};
use foresight::util::benchkit::{MdTable, Report};

/// All-compute policy at sublayer granularity so the op-level timers see
/// attention / cross / MLP separately.
struct AllComputeFine;

impl ReusePolicy for AllComputeFine {
    fn name(&self) -> String {
        "all-compute-fine".into()
    }
    fn granularity(&self) -> Granularity {
        Granularity::Fine
    }
    fn cache_mode(&self) -> CacheMode {
        CacheMode::Delta
    }
    fn begin_request(&mut self, _l: usize, _s: usize) {}
    fn action(&mut self, _step: usize, _site: Site) -> Action {
        Action::Compute { update_cache: false, measure: false }
    }
}

fn main() -> anyhow::Result<()> {
    let mut ctx = BenchCtx::new()?;
    let mut report = Report::new(
        "fig9",
        "Figure 9 — latency across resolutions + operator breakdown (opensora-sim)",
    );

    // --- left: end-to-end latency vs resolution ----------------------------
    let mut tl = MdTable::new(&["resolution", "tokens/frame", "latency (s)"]);
    let mut lat = Vec::new();
    for bucket in ["240p-2s", "480p-2s", "720p-2s"] {
        let engine = ctx.engine("opensora-sim", bucket)?;
        let _ = run_one(&engine, "none", "warmup", 0, Some(2))?;
        let r = run_one(&engine, "none", "a lighthouse at dusk on a rocky coast", 1, None)?;
        lat.push(r.stats.wall_s);
        tl.row(vec![
            bucket.into(),
            engine.model().bucket.tokens.to_string(),
            format!("{:.2}", r.stats.wall_s),
        ]);
    }
    report.table("left: latency vs resolution", &tl);
    report.csv("latency", &tl);
    report.text(&format!(
        "720p/240p latency ratio: {:.2} (paper: 2.5x for 480p→720p on A100)",
        lat[2] / lat[0]
    ));

    // --- right: operator breakdown at sub-block granularity ----------------
    let engine = ctx.engine("opensora-sim", "480p-2s")?;
    engine.model().reset_op_stats();
    let mut pol = AllComputeFine;
    engine.generate(
        &Request::new("a lighthouse at dusk on a rocky coast", 1),
        &mut pol,
        None,
    )?;
    let stats = engine.model().op_stats();
    let total: f64 = stats.iter().map(|(_, _, s)| s).sum();
    let mut tr = MdTable::new(&["operator", "calls", "time (s)", "share %"]);
    let mut grouped: Vec<(&str, f64, u64)> = Vec::new();
    let group_of = |name: &str| -> &'static str {
        if name.contains("sb_attn") {
            "self/temporal attention"
        } else if name.contains("sb_cross") {
            "cross attention"
        } else if name.contains("sb_mlp") {
            "FFN (MLP)"
        } else if name.contains("embed") || name.contains("final") || name.contains("text") {
            "embed/final/text (glue)"
        } else {
            "other"
        }
    };
    for (name, calls, secs) in &stats {
        let g = group_of(name);
        if let Some(e) = grouped.iter_mut().find(|(n, _, _)| *n == g) {
            e.1 += secs;
            e.2 += calls;
        } else {
            grouped.push((g, *secs, *calls));
        }
    }
    grouped.sort_by(|a, b| b.1.total_cmp(&a.1));
    for (g, secs, calls) in &grouped {
        tr.row(vec![
            (*g).into(),
            calls.to_string(),
            format!("{secs:.3}"),
            format!("{:.1}", 100.0 * secs / total),
        ]);
    }
    report.table("right: operator breakdown (sub-block dispatch, 480p)", &tr);
    report.csv("breakdown", &tr);
    report.finish()?;
    let _ = Unit::Block;
    Ok(())
}
