//! Reproduces **Figure 3**: (a) prompt-dependent feature dynamics — static
//! vs dynamic prompts' per-step MSE curves; (b) layer-group sensitivity —
//! static reuse (N=1) applied to only the early / middle / late third of
//! layers and the resulting quality drop.
//!
//! Paper shape: dynamic prompts show sharper inter-step variation; reusing
//! the LATE layer group degrades quality the most.

use foresight::analysis::DynamicsRecorder;
use foresight::bench_support::{run_one, BenchCtx};
use foresight::cache::Unit;
use foresight::engine::Request;
use foresight::metrics::{psnr, Decoder, FeatureNet};
use foresight::model::BlockKind;
use foresight::policy::{build_policy, Action, CacheMode, Granularity, ReusePolicy, Site};
use foresight::util::benchkit::{MdTable, Report};
use foresight::util::stats;

/// Static N=1/R=2 reuse restricted to a layer range — the Fig. 3b probe.
struct GroupStatic {
    lo: usize,
    hi: usize,
}

impl ReusePolicy for GroupStatic {
    fn name(&self) -> String {
        format!("group-static[{}..{})", self.lo, self.hi)
    }
    fn granularity(&self) -> Granularity {
        Granularity::Coarse
    }
    fn cache_mode(&self) -> CacheMode {
        CacheMode::Output
    }
    fn begin_request(&mut self, _layers: usize, _steps: usize) {}
    fn action(&mut self, step: usize, site: Site) -> Action {
        let in_group = site.layer >= self.lo && site.layer < self.hi;
        if !in_group || step % 2 == 0 {
            Action::Compute { update_cache: in_group, measure: false }
        } else {
            Action::Reuse
        }
    }
}

fn main() -> anyhow::Result<()> {
    let mut ctx = BenchCtx::new()?;
    let mut report = Report::new(
        "fig3",
        "Figure 3 — prompt-dependent dynamics and layer-group sensitivity",
    );

    // --- (a) prompt-dependent per-step dynamics ----------------------------
    let engine = ctx.engine("analysis", "240p-2s")?;
    let info = engine.model().info.clone();
    let probe_layer = info.layers - 1;
    let mut ta = MdTable::new(&["step", "static prompt MSE", "dynamic prompt MSE"]);
    let mut curves = Vec::new();
    for prompt in [
        "a tranquil zen garden with still stones and soft morning light",
        "a racecar crashing through barriers, explosions, rapid camera spin",
    ] {
        let mut rec = DynamicsRecorder::new();
        let mut pol = build_policy("none", &info, info.steps)?;
        engine.generate(&Request::new(prompt, 5), pol.as_mut(), Some(&mut rec))?;
        let curve: Vec<(usize, f64)> = rec
            .step_mse
            .iter()
            .map(|(s, m)| (*s, m.get(&(probe_layer, BlockKind::Spatial)).copied().unwrap_or(0.0)))
            .collect();
        curves.push(curve);
    }
    for i in 0..curves[0].len() {
        ta.row(vec![
            curves[0][i].0.to_string(),
            format!("{:.4e}", curves[0][i].1),
            format!("{:.4e}", curves[1][i].1),
        ]);
    }
    report.table("(a) per-step MSE, last layer, static vs dynamic prompt", &ta);
    report.csv("prompt_dynamics", &ta);
    let mean_static: f64 = stats::mean(&curves[0].iter().map(|c| c.1).collect::<Vec<_>>());
    let mean_dynamic: f64 = stats::mean(&curves[1].iter().map(|c| c.1).collect::<Vec<_>>());
    report.text(&format!(
        "dynamic/static prompt MSE ratio: {:.2} (paper: dynamic prompts vary more)",
        mean_dynamic / mean_static.max(1e-12)
    ));

    // --- (b) layer-group sensitivity on opensora-sim -----------------------
    let engine = ctx.engine("opensora-sim", "240p-2s")?;
    let info = engine.model().info.clone();
    let dec = Decoder::new(engine.model().bucket.ph, engine.model().bucket.pw, info.latent_channels);
    let net = FeatureNet::new();
    let l3 = info.layers / 3;
    let groups = [
        ("early", 0, l3.max(1)),
        ("middle", l3, (2 * l3).max(l3 + 1)),
        ("late", 2 * l3, info.layers),
    ];
    let prompt = "a playful black labrador frolics in a sunlit autumn garden";
    let base = run_one(&engine, "none", prompt, 9, None)?;
    let base_frames = dec.decode(&base.latents);

    let mut tb = MdTable::new(&["reused group", "layers", "PSNR vs baseline", "VBench(%)"]);
    for (name, lo, hi) in groups {
        let mut pol = GroupStatic { lo, hi };
        let r = engine.generate(&Request::new(prompt, 9), &mut pol, None)?;
        let fr = dec.decode(&r.latents);
        tb.row(vec![
            name.into(),
            format!("[{lo}..{hi})"),
            format!("{:.2}", psnr(&base_frames, &fr)),
            format!("{:.2}", foresight::metrics::vbench_evaluate(&net, &fr).overall()),
        ]);
    }
    report.table("(b) static reuse (N=1) per layer group", &tb);
    report.csv("group_sensitivity", &tb);
    report.finish()?;
    let _ = Unit::Block; // silence unused import if optimised out
    Ok(())
}
