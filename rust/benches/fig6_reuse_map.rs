//! Reproduces **Figure 6**: Foresight's compute/reuse decision map over
//! layers × denoising steps on OpenSora-sim (240p, 4s, W=15%, N=1, R=2,
//! γ=0.5), with the warmup prefix computing everything and adaptive
//! alternation afterwards.

use foresight::bench_support::BenchCtx;
use foresight::engine::Request;
use foresight::policy::build_policy;
use foresight::util::benchkit::{MdTable, Report};

fn main() -> anyhow::Result<()> {
    let mut ctx = BenchCtx::new()?;
    let engine = ctx.engine("opensora-sim", "240p-4s")?;
    let info = engine.model().info.clone();

    let prompt = "a playful black labrador in a pumpkin halloween costume \
                  frolics in a sunlit autumn garden surrounded by fallen leaves";
    let mut pol = build_policy("foresight:n=1,r=2,gamma=0.5,warmup=0.15", &info, info.steps)?;
    let r = engine.generate(&Request::new(prompt, 6), pol.as_mut(), None)?;

    let mut report = Report::new(
        "fig6",
        "Figure 6 — Foresight reuse/compute map (opensora-sim, 240p, 4s, N=1 R=2 γ=0.5)",
    );
    report.text(&format!(
        "wall {:.2}s, reuse {:.0}% (✓=compute, →=reuse)\n",
        r.stats.wall_s,
        100.0 * r.stats.reuse_fraction()
    ));

    // CSV: rows = sites, cols = steps
    let n_sites = info.layers * 2;
    let mut header: Vec<String> = vec!["block".into()];
    header.extend((0..r.reuse_map.len()).map(|s| format!("s{s}")));
    let mut t = MdTable::new(
        &header.iter().map(|s| Box::leak(s.clone().into_boxed_str()) as &str).collect::<Vec<_>>(),
    );
    let mut ascii = String::new();
    for site in 0..n_sites {
        let layer = site / 2;
        let kind = if site % 2 == 0 { "S" } else { "T" };
        let mut row = vec![format!("L{layer:02}{kind}")];
        let mut line = format!("  L{layer:02}{kind} ");
        for step in &r.reuse_map {
            row.push(step[site].name().into());
            line.push(if step[site].is_reuse() { '→' } else { '✓' });
        }
        t.row(row);
        ascii.push_str(&line);
        ascii.push('\n');
    }
    report.csv("map", &t);
    report.text(&format!("```\n{ascii}```"));

    // per-layer reuse counts (the paper's "later layers recompute more")
    let mut counts = MdTable::new(&["layer", "reuse count (spatial)", "reuse count (temporal)"]);
    for layer in 0..info.layers {
        let c = |k: usize| {
            r.reuse_map
                .iter()
                .filter(|step| step[layer * 2 + k].is_reuse())
                .count()
        };
        counts.row(vec![layer.to_string(), c(0).to_string(), c(1).to_string()]);
    }
    report.table("per-layer reuse totals", &counts);
    report.finish()?;
    Ok(())
}
