//! **Figure 17 (repo-original)**: device-resident denoising state.
//!
//! A/B of [`HotPath::Device`] — the latent uploads once, rflow Euler steps
//! as a fused `axpy` and DDIM as a fused `ddim_step`, the CFG combine
//! feeds the sampler directly, and the final latent downloads once —
//! against [`HotPath::Host`], the seed-era staging that uploads the latent
//! and downloads both branch epsilons every step and advances `x` in a
//! host loop.
//!
//! Steady-state per-step traffic is isolated by differencing two runs of
//! the same request at different step counts (request-start constants and
//! the final download cancel). Asserted per (model, policy):
//!
//! * ≥100× lower steady-state host↔device bytes per step on the device
//!   path, for both sampler families (acceptance criterion);
//! * final latents matching the host sampler to ≤1e-6 per element;
//! * the engine's [`RunStats`] byte counters agreeing exactly with the
//!   runtime's global `TransferStats` meter.

use foresight::bench_support::{first_latent_mismatch, steady_state_bytes_per_step, BenchCtx};
use foresight::engine::{HotPath, Request, RunResult};
use foresight::policy::build_policy;
use foresight::util::benchkit::{MdTable, Report};

/// (model, bucket, sampler family) — one rflow preset, one DDIM preset.
const MODELS: [(&str, &str, &str); 2] = [
    ("opensora-sim", "240p-2s", "rflow"),
    ("latte-sim", "512sq-2s", "ddim"),
];

const POLICIES: [(&str, &str); 2] = [
    ("Baseline", "none"),
    ("Foresight (N1R2)", "foresight:n=1,r=2,gamma=0.5"),
];

const SHORT_STEPS: usize = 8;
const LONG_STEPS: usize = 24;

fn run(
    ctx: &mut BenchCtx,
    model: &str,
    bucket: &str,
    hot: HotPath,
    spec: &str,
    steps: usize,
) -> anyhow::Result<RunResult> {
    let engine = ctx.engine_hot(model, bucket, hot)?;
    let info = engine.model().info.clone();
    let mut policy = build_policy(spec, &info, steps)?;
    let mut req = Request::new("a paper lantern drifting over a midnight lake", 11);
    req.steps = Some(steps);
    engine.generate(&req, policy.as_mut(), None)
}

fn main() -> anyhow::Result<()> {
    let mut ctx = BenchCtx::new()?;
    let mut report = Report::new(
        "fig17",
        "Figure 17 — device-resident denoising state: steady-state transfer A/B",
    );
    let mut t = MdTable::new(&[
        "Model",
        "Sampler",
        "Policy",
        "Mode",
        "Steady h2d B/step",
        "Steady d2h B/step",
        "Reduction",
        "Latents",
    ]);

    for (model, bucket, sampler) in MODELS {
        // Warm both engines (compile caches) before measuring.
        for hot in [HotPath::Device, HotPath::Host] {
            let _ = run(&mut ctx, model, bucket, hot, "none", 2)?;
        }
        for (pname, spec) in POLICIES {
            // Cross-check the engine's per-run byte meters against the
            // runtime's global transfer meter (nothing else touches the
            // runtime between the snapshots).
            let before = ctx.runtime().transfer_stats().snapshot();
            let dev_short = run(&mut ctx, model, bucket, HotPath::Device, spec, SHORT_STEPS)?;
            let rt_delta = ctx.runtime().transfer_stats().snapshot().delta_since(&before);
            assert_eq!(
                rt_delta.h2d_bytes, dev_short.stats.h2d_bytes,
                "{model}/{pname}: engine h2d byte meter disagrees with runtime meter"
            );
            assert_eq!(
                rt_delta.d2h_bytes, dev_short.stats.d2h_bytes,
                "{model}/{pname}: engine d2h byte meter disagrees with runtime meter"
            );
            assert_eq!(
                rt_delta.h2d_calls, dev_short.stats.h2d_calls,
                "{model}/{pname}: engine h2d call meter disagrees with runtime meter"
            );
            assert_eq!(
                rt_delta.d2h_calls, dev_short.stats.d2h_calls,
                "{model}/{pname}: engine d2h call meter disagrees with runtime meter"
            );

            let dev_long = run(&mut ctx, model, bucket, HotPath::Device, spec, LONG_STEPS)?;
            let host_short = run(&mut ctx, model, bucket, HotPath::Host, spec, SHORT_STEPS)?;
            let host_long = run(&mut ctx, model, bucket, HotPath::Host, spec, LONG_STEPS)?;

            let (dev_h2d, dev_d2h) = steady_state_bytes_per_step(&dev_short.stats, &dev_long.stats);
            let (host_h2d, host_d2h) =
                steady_state_bytes_per_step(&host_short.stats, &host_long.stats);
            let dev_total = dev_h2d + dev_d2h;
            let host_total = host_h2d + host_d2h;
            let reduction = host_total / dev_total.max(1.0);

            // Acceptance: ≥100× steady-state per-step traffic reduction.
            assert!(
                reduction >= 100.0,
                "{model}/{pname}: expected ≥100x steady-state per-step transfer \
                 reduction, got {reduction:.1}x (host {host_total:.0} B/step, \
                 device {dev_total:.0} B/step)"
            );

            // Acceptance: final latents match the host sampler to ≤1e-6.
            let mismatch =
                first_latent_mismatch(&dev_long.latents.data, &host_long.latents.data, 1e-6);
            assert!(
                mismatch.is_none(),
                "{model}/{pname}: device latents diverged from host sampler \
                 (first mismatch: {mismatch:?})"
            );

            for (mode, h2d, d2h) in [
                ("device", dev_h2d, dev_d2h),
                ("host", host_h2d, host_d2h),
            ] {
                t.row(vec![
                    model.into(),
                    sampler.into(),
                    pname.into(),
                    mode.into(),
                    format!("{h2d:.1}"),
                    format!("{d2h:.1}"),
                    if mode == "device" { format!("{reduction:.0}x") } else { "1x".into() },
                    "≤1e-6".into(),
                ]);
            }
            println!(
                "[fig17] {model}/{pname}: {reduction:.0}x steady-state reduction, \
                 latents ≤1e-6"
            );
        }
    }

    report.table("steady-state per-step transfer volume (B/step)", &t);
    report.csv("series", &t);
    report.text(
        "\nDevice mode keeps the latent resident for the whole request: steady-state \
         per-step traffic is the per-step schedule scalars (uploaded at request \
         start) plus 4 bytes per measured site for measuring policies, vs. a full \
         latent up and two epsilons down per step for the seed staging.",
    );
    report.finish()?;
    Ok(())
}
