//! **Figure 18 (repo-original)**: micro-batched serving throughput.
//!
//! Runs the same four Foresight requests (distinct prompts and seeds)
//! through [`Engine::generate_batch`] at B ∈ {1, 2, 4} and through the
//! sequential device path, and asserts the batching contract:
//!
//! * per-request latents from the B=4 batch match the sequential
//!   [`HotPath::Device`] path to ≤1e-6 per element (the batched trajectory
//!   is elementwise-identical — stack/lane are pure data movement);
//! * per-request d2h transfer stays at the resident steady-state budget —
//!   byte-for-byte equal to the sequential run (4 B per measured site plus
//!   one final latent), i.e. batching adds **zero** download traffic; the
//!   as-if h2d meter matches too (engine docs §Micro-batching);
//! * batched wall-clock per request at B=4 is below the sequential
//!   per-request wall-clock, and requests/s scales sub-linearly in wall
//!   time across B (the lanes share one step loop, one batched
//!   `cfg_combine` + sampler step per step, and co-run their site sweeps).
//!
//! `FORESIGHT_BENCH_STEPS` overrides the step count (CI smoke mode runs a
//! reduced schedule). Exits cleanly with a SKIP note when the AOT
//! artifacts are absent (e.g. hosted CI).

use foresight::bench_support::{first_latent_mismatch, BenchCtx};
use foresight::engine::{Engine, Request, RunResult};
use foresight::policy::{build_policy, ReusePolicy};
use foresight::util::benchkit::{MdTable, Report};

const MODEL: (&str, &str) = ("opensora-sim", "240p-2s");
const POLICY: &str = "foresight:n=1,r=2,gamma=0.5";
const BATCH_SIZES: [usize; 3] = [1, 2, 4];
const PROMPTS: [&str; 4] = [
    "a paper lantern drifting over a midnight lake",
    "a fox darting through fresh snow at dawn",
    "waves crashing against a basalt cliff in a storm",
    "a quiet greenhouse, sunlight through fogged glass",
];

fn bench_steps() -> usize {
    std::env::var("FORESIGHT_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10)
        .max(2)
}

fn requests(n: usize, steps: usize) -> Vec<Request> {
    (0..n)
        .map(|i| {
            let mut r = Request::new(PROMPTS[i % PROMPTS.len()], 100 + i as u64);
            r.steps = Some(steps);
            r
        })
        .collect()
}

fn policies(engine: &Engine, n: usize, steps: usize) -> anyhow::Result<Vec<Box<dyn ReusePolicy>>> {
    let info = engine.model().info.clone();
    (0..n).map(|_| build_policy(POLICY, &info, steps)).collect()
}

fn run_batch(engine: &Engine, n: usize, steps: usize) -> anyhow::Result<(f64, Vec<RunResult>)> {
    let reqs = requests(n, steps);
    let mut pols = policies(engine, n, steps)?;
    let t0 = std::time::Instant::now();
    let results = engine.generate_batch(&reqs, &mut pols)?;
    Ok((t0.elapsed().as_secs_f64(), results))
}

fn run_sequential(
    engine: &Engine,
    n: usize,
    steps: usize,
) -> anyhow::Result<(f64, Vec<RunResult>)> {
    let reqs = requests(n, steps);
    let mut out = Vec::with_capacity(n);
    let t0 = std::time::Instant::now();
    for (req, mut pol) in reqs.iter().zip(policies(engine, n, steps)?) {
        out.push(engine.generate(req, pol.as_mut(), None)?);
    }
    Ok((t0.elapsed().as_secs_f64(), out))
}

fn main() -> anyhow::Result<()> {
    let mut ctx = match BenchCtx::new() {
        Ok(c) => c,
        Err(e) => {
            println!("[fig18] SKIP: artifacts unavailable ({e:#}); run `make artifacts`");
            return Ok(());
        }
    };
    let steps = bench_steps();
    let engine = ctx.engine(MODEL.0, MODEL.1)?;
    let nmax = *BATCH_SIZES.iter().max().unwrap();

    // Warm the compile caches for every shape this bench touches: the
    // sequential [F,P,C] fused ops and each batch size's [B,F,P,C]
    // variants (first-use compiles would otherwise skew the timings).
    let _ = run_sequential(&engine, 1, 2)?;
    for &b in &BATCH_SIZES {
        let _ = run_batch(&engine, b, 2)?;
    }

    let mut report = Report::new(
        "fig18",
        "Figure 18 — micro-batched serving: throughput and per-request equivalence",
    );
    let mut t = MdTable::new(&[
        "B",
        "Wall(s)",
        "Wall/req (s)",
        "Requests/s",
        "Speedup vs B=1",
        "d2h B/req",
        "Latents",
    ]);

    // Sequential reference (two passes, keep the faster — dispatch noise).
    let (seq_wall_a, seq_results) = run_sequential(&engine, nmax, steps)?;
    let (seq_wall_b, _) = run_sequential(&engine, nmax, steps)?;
    let seq_wall = seq_wall_a.min(seq_wall_b);
    let seq_per_req = seq_wall / nmax as f64;

    let mut per_req_at = vec![0.0f64; BATCH_SIZES.len()];
    let mut batch4: Option<Vec<RunResult>> = None;
    for (bi, &b) in BATCH_SIZES.iter().enumerate() {
        let (wall_a, results) = run_batch(&engine, b, steps)?;
        let (wall_b, _) = run_batch(&engine, b, steps)?;
        let wall = wall_a.min(wall_b);
        let per_req = wall / b as f64;
        per_req_at[bi] = per_req;
        let d2h_per_req = results.iter().map(|r| r.stats.d2h_bytes).sum::<u64>() / b as u64;
        let close = results
            .iter()
            .zip(&seq_results)
            .all(|(br, sr)| {
                first_latent_mismatch(&br.latents.data, &sr.latents.data, 1e-6).is_none()
            });
        t.row(vec![
            format!("{b}"),
            format!("{wall:.3}"),
            format!("{per_req:.3}"),
            format!("{:.2}", b as f64 / wall),
            format!("{:.2}x", per_req_at[0] / per_req),
            format!("{d2h_per_req}"),
            if close { "≤1e-6".into() } else { "DIVERGED".into() },
        ]);
        if b == nmax {
            batch4 = Some(results);
        }
    }
    let batch4 = batch4.expect("B=4 measured");
    let batch4_per_req = per_req_at[BATCH_SIZES.len() - 1];

    // --- acceptance: per-request results match the sequential device path
    for (lane, (br, sr)) in batch4.iter().zip(&seq_results).enumerate() {
        let mismatch = first_latent_mismatch(&br.latents.data, &sr.latents.data, 1e-6);
        assert!(
            mismatch.is_none(),
            "lane {lane}: batched latents diverged from the sequential device \
             path (first mismatch: {mismatch:?})"
        );
        // decisions (and thus unit counters) must be identical too
        assert_eq!(
            (br.stats.computed_units, br.stats.reused_units, br.stats.fallback_units),
            (sr.stats.computed_units, sr.stats.reused_units, sr.stats.fallback_units),
            "lane {lane}: batched reuse decisions diverged from sequential"
        );
    }

    // --- acceptance: per-request transfers stay at the resident budget.
    // d2h is byte-for-byte the sequential cost (drift scalars + one final
    // latent); the as-if h2d meter matches the standalone cost by
    // construction (engine docs §Micro-batching).
    for (lane, (br, sr)) in batch4.iter().zip(&seq_results).enumerate() {
        assert_eq!(
            br.stats.d2h_bytes, sr.stats.d2h_bytes,
            "lane {lane}: batching changed the per-request d2h budget"
        );
        assert_eq!(
            br.stats.h2d_bytes, sr.stats.h2d_bytes,
            "lane {lane}: batching changed the per-request (as-if) h2d budget"
        );
    }

    // --- acceptance: batching buys wall-clock per request at B=4.
    assert!(
        batch4_per_req < seq_per_req,
        "expected batched wall/request at B=4 ({batch4_per_req:.3}s) below the \
         sequential per-request wall ({seq_per_req:.3}s)"
    );

    t.row(vec![
        "seq".into(),
        format!("{seq_wall:.3}"),
        format!("{seq_per_req:.3}"),
        format!("{:.2}", nmax as f64 / seq_wall),
        "—".into(),
        format!("{}", seq_results.iter().map(|r| r.stats.d2h_bytes).sum::<u64>() / nmax as u64),
        "ref".into(),
    ]);
    report.table("micro-batched throughput (requests/s) and equivalence", &t);
    report.csv("series", &t);
    report.text(&format!(
        "\nB=4 serves each request in {batch4_per_req:.3}s vs {seq_per_req:.3}s \
         sequentially ({:.2}x): one shared step loop, one batched cfg_combine + \
         sampler step per step, per-request latents and transfer budgets unchanged.",
        seq_per_req / batch4_per_req
    ));
    report.finish()?;
    Ok(())
}
