//! **Figure 20 (repo-original)**: continuous step-level batching vs the
//! retired lockstep gather-window scheduler.
//!
//! Replays one staggered (Poisson-ish, deterministic seed) arrival
//! schedule of mixed-step requests through two scheduling disciplines on
//! the same engine:
//!
//! * **lockstep** — the pre-session scheduler: a worker picks up the
//!   first queued job, waits out a gather window, batches only jobs with
//!   an identical (policy, steps, cfg) key, and runs the whole batch
//!   request-lockstep via [`Engine::generate_batch`]; late arrivals wait
//!   for the next pass and mixed step counts never share one.
//! * **continuous** — the session scheduler: lanes join at step
//!   boundaries up to `max_batch`, retire the moment their own schedule
//!   completes, and mixed step counts share fused passes
//!   ([`foresight::engine::step_many_refs`]).
//!
//! Arrival times are virtual (seeded, identical for both disciplines);
//! execution costs are **real measured walls** of the engine passes, so
//! the comparison is deterministic up to CPU noise without needing live
//! threads. Asserts the continuous contract:
//!
//! * per-request latents from the continuous cohort match each request's
//!   standalone device run to ≤1e-6;
//! * p50 latency is no worse than lockstep (small tolerance for noise);
//! * throughput (requests / makespan) is no worse than lockstep.
//!
//! `FORESIGHT_BENCH_STEPS` overrides the step count (CI smoke mode).
//! Exits cleanly with a SKIP note when the AOT artifacts are absent.

use std::time::Instant;

use foresight::bench_support::{first_latent_mismatch, BenchCtx};
use foresight::engine::{step_many_refs, Engine, Request, RunResult, Session};
use foresight::policy::{build_policy, ReusePolicy};
use foresight::util::benchkit::{MdTable, Report};
use foresight::util::json::Json;
use foresight::util::prng::Rng;
use foresight::util::stats;

const MODEL: (&str, &str) = ("opensora-sim", "240p-2s");
const POLICY: &str = "foresight:n=1,r=2,gamma=0.5";
const MAX_BATCH: usize = 4;
/// The retired scheduler's default gather window, in seconds.
const GATHER_S: f64 = 0.002;
const N_REQS: usize = 6;
const PROMPTS: [&str; 6] = [
    "a paper lantern drifting over a midnight lake",
    "a fox darting through fresh snow at dawn",
    "waves crashing against a basalt cliff in a storm",
    "a quiet greenhouse, sunlight through fogged glass",
    "a tram crossing a rainy neon intersection",
    "dust motes in a sunbeam over an old library",
];

fn bench_steps() -> usize {
    std::env::var("FORESIGHT_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10)
        .max(4)
}

/// Mixed-step workload: even requests run the full schedule, odd ones
/// half of it — under the old batch key these never share a pass.
fn requests(steps_full: usize) -> Vec<Request> {
    let steps_half = (steps_full / 2).max(2);
    (0..N_REQS)
        .map(|i| {
            let mut r = Request::new(PROMPTS[i % PROMPTS.len()], 300 + i as u64);
            r.steps = Some(if i % 2 == 0 { steps_full } else { steps_half });
            r
        })
        .collect()
}

fn policy_for(engine: &Engine, req: &Request) -> anyhow::Result<Box<dyn ReusePolicy>> {
    let info = &engine.model().info;
    build_policy(POLICY, info, req.steps.unwrap_or(info.steps))
}

fn standalone(engine: &Engine, req: &Request) -> anyhow::Result<RunResult> {
    let mut pol = policy_for(engine, req)?;
    engine.generate(req, pol.as_mut(), None)
}

struct SimOutcome {
    latencies: Vec<f64>,
    makespan: f64,
    mean_occupancy: f64,
    results: Vec<Option<RunResult>>,
}

/// Event-driven replay of the continuous scheduler: admissions at step
/// boundaries, eager retirement, real measured pass walls on a virtual
/// arrival clock.
fn continuous_sim(
    engine: &Engine,
    reqs: &[Request],
    arrivals: &[f64],
) -> anyhow::Result<SimOutcome> {
    let mut vnow = 0.0f64;
    let mut next = 0usize;
    let mut lanes: Vec<(Session<'static>, f64, usize)> = Vec::new();
    let mut latencies = vec![0.0f64; reqs.len()];
    let mut results: Vec<Option<RunResult>> = (0..reqs.len()).map(|_| None).collect();
    let (mut occ_sum, mut occ_n) = (0.0f64, 0u64);
    let mut last_done = 0.0f64;

    while next < reqs.len() || !lanes.is_empty() {
        if lanes.is_empty() && next < reqs.len() && arrivals[next] > vnow {
            // empty queue: the worker just sleeps until the next arrival —
            // no window is waited out.
            vnow = arrivals[next];
        }
        while next < reqs.len() && arrivals[next] <= vnow && lanes.len() < MAX_BATCH {
            let t0 = Instant::now();
            let pol = policy_for(engine, &reqs[next])?;
            let s = engine.admit(&reqs[next], pol)?;
            vnow += t0.elapsed().as_secs_f64();
            lanes.push((s, arrivals[next], next));
            next += 1;
        }
        let t0 = Instant::now();
        {
            let mut refs: Vec<&mut Session> = lanes.iter_mut().map(|(s, _, _)| s).collect();
            step_many_refs(&mut refs)?;
        }
        vnow += t0.elapsed().as_secs_f64();
        occ_sum += lanes.len() as f64;
        occ_n += 1;
        let mut i = 0;
        while i < lanes.len() {
            if lanes[i].0.is_done() {
                let (s, arr, idx) = lanes.remove(i);
                let t0 = Instant::now();
                let r = s.finish()?;
                vnow += t0.elapsed().as_secs_f64();
                latencies[idx] = vnow - arr;
                results[idx] = Some(r);
                last_done = vnow;
            } else {
                i += 1;
            }
        }
    }
    Ok(SimOutcome {
        latencies,
        makespan: last_done - arrivals[0],
        mean_occupancy: occ_sum / occ_n.max(1) as f64,
        results,
    })
}

/// Event-driven replay of the retired lockstep scheduler: pick up the
/// first job, always wait the gather window out (the single-worker
/// pathology this PR removes), batch only identical-steps jobs that have
/// arrived by the deadline, run the whole batch lockstep.
fn lockstep_sim(engine: &Engine, reqs: &[Request], arrivals: &[f64]) -> anyhow::Result<SimOutcome> {
    let mut vnow = 0.0f64;
    let mut remaining: Vec<usize> = (0..reqs.len()).collect();
    let mut latencies = vec![0.0f64; reqs.len()];
    let (mut occ_sum, mut occ_n) = (0.0f64, 0u64);
    let mut last_done = 0.0f64;

    while !remaining.is_empty() {
        let first = remaining[0];
        let pickup = vnow.max(arrivals[first]);
        let deadline = pickup + GATHER_S;
        let mut batch_idx = vec![first];
        for &j in remaining.iter().skip(1) {
            if batch_idx.len() >= MAX_BATCH {
                break;
            }
            if reqs[j].steps == reqs[first].steps && arrivals[j] <= deadline {
                batch_idx.push(j);
            }
        }
        remaining.retain(|j| !batch_idx.contains(j));

        let breqs: Vec<Request> = batch_idx.iter().map(|&j| reqs[j].clone()).collect();
        let mut pols: Vec<Box<dyn ReusePolicy>> = breqs
            .iter()
            .map(|r| policy_for(engine, r))
            .collect::<anyhow::Result<_>>()?;
        let t0 = Instant::now();
        let _ = engine.generate_batch(&breqs, &mut pols)?;
        let wall = t0.elapsed().as_secs_f64();
        let done = deadline + wall;
        for &j in &batch_idx {
            latencies[j] = done - arrivals[j];
        }
        occ_sum += batch_idx.len() as f64;
        occ_n += 1;
        vnow = done;
        last_done = done;
    }
    Ok(SimOutcome {
        latencies,
        makespan: last_done - arrivals[0],
        mean_occupancy: occ_sum / occ_n.max(1) as f64,
        results: Vec::new(),
    })
}

fn main() -> anyhow::Result<()> {
    let mut ctx = match BenchCtx::new() {
        Ok(c) => c,
        Err(e) => {
            println!("[fig20] SKIP: artifacts unavailable ({e:#}); run `make artifacts`");
            return Ok(());
        }
    };
    let steps = bench_steps();
    let engine = ctx.engine(MODEL.0, MODEL.1)?;
    let reqs = requests(steps);

    // Standalone oracles (also the per-step wall calibration for the
    // arrival process).
    let mut oracles = Vec::with_capacity(reqs.len());
    for r in &reqs {
        oracles.push(standalone(&engine, r)?);
    }
    let step_wall = {
        let s = &oracles[0].stats;
        s.wall_s / s.per_step_s.len().max(1) as f64
    };

    // Poisson-ish arrivals, deterministic seed, mean gap ≈ 1.5 step walls
    // so the schedule genuinely staggers across pass boundaries.
    let mut rng = Rng::from_seed_and_label(7, "fig20-arrivals");
    let mut arrivals = Vec::with_capacity(reqs.len());
    let mut t = 0.0f64;
    for _ in 0..reqs.len() {
        let u = (rng.next_f64()).clamp(1e-6, 1.0 - 1e-6);
        t += -(1.5 * step_wall) * u.ln();
        arrivals.push(t);
    }

    // Two passes per discipline: the first warms every fused-shape cache
    // (cohort steps at each B, regroup keep-lists, batched stacks), the
    // second is measured.
    let _ = lockstep_sim(&engine, &reqs, &arrivals)?;
    let lock = lockstep_sim(&engine, &reqs, &arrivals)?;
    let _ = continuous_sim(&engine, &reqs, &arrivals)?;
    let cont = continuous_sim(&engine, &reqs, &arrivals)?;

    // --- acceptance: per-request latents match standalone runs --------
    for (i, (got, want)) in cont.results.iter().zip(&oracles).enumerate() {
        let got = got.as_ref().expect("continuous sim finished every request");
        let mismatch = first_latent_mismatch(&got.latents.data, &want.latents.data, 1e-6);
        assert!(
            mismatch.is_none(),
            "request {i}: continuous-cohort latents diverged from standalone \
             (first mismatch: {mismatch:?})"
        );
        assert_eq!(
            (got.stats.computed_units, got.stats.reused_units),
            (want.stats.computed_units, want.stats.reused_units),
            "request {i}: decisions diverged"
        );
    }

    let p50_cont = stats::percentile(&cont.latencies, 50.0);
    let p50_lock = stats::percentile(&lock.latencies, 50.0);
    let p95_cont = stats::percentile(&cont.latencies, 95.0);
    let p95_lock = stats::percentile(&lock.latencies, 95.0);
    let thr_cont = reqs.len() as f64 / cont.makespan;
    let thr_lock = reqs.len() as f64 / lock.makespan;

    // --- acceptance: p50 no worse, throughput no worse (small noise
    // tolerance; the structural win is large — mixed steps cannot batch
    // at all under the lockstep key).
    assert!(
        p50_cont <= p50_lock * 1.10 + 0.05,
        "continuous p50 {p50_cont:.3}s worse than lockstep {p50_lock:.3}s"
    );
    assert!(
        thr_cont >= thr_lock * 0.90,
        "continuous throughput {thr_cont:.2}/s below lockstep {thr_lock:.2}/s"
    );

    let mut report = Report::new(
        "fig20",
        "Figure 20 — continuous step-level batching vs lockstep gather-window",
    );
    report.config("model", Json::str(MODEL.0));
    report.config("bucket", Json::str(MODEL.1));
    report.config("policy", Json::str(POLICY));
    report.config("steps", Json::num(steps as f64));
    report.config("requests", Json::num(N_REQS as f64));
    report.config("max_batch", Json::num(MAX_BATCH as f64));
    report.metric("wall_s", cont.makespan);
    report.metric("throughput_rps", thr_cont);
    report.metric("p50_s", p50_cont);
    report.metric("p95_s", p95_cont);
    report.metric("p99_s", stats::percentile(&cont.latencies, 99.0));
    report.metric("lockstep_wall_s", lock.makespan);
    report.metric("lockstep_throughput_rps", thr_lock);
    report.metric("lockstep_p50_s", p50_lock);
    report.metric("lockstep_p95_s", p95_lock);
    report.metric("mean_occupancy", cont.mean_occupancy);
    let mut tbl = MdTable::new(&[
        "Scheduler",
        "Makespan(s)",
        "Req/s",
        "p50 lat(s)",
        "p95 lat(s)",
        "Mean lanes/pass",
    ]);
    tbl.row(vec![
        "lockstep".into(),
        format!("{:.3}", lock.makespan),
        format!("{thr_lock:.2}"),
        format!("{p50_lock:.3}"),
        format!("{p95_lock:.3}"),
        format!("{:.2}", lock.mean_occupancy),
    ]);
    tbl.row(vec![
        "continuous".into(),
        format!("{:.3}", cont.makespan),
        format!("{thr_cont:.2}"),
        format!("{p50_cont:.3}"),
        format!("{p95_cont:.3}"),
        format!("{:.2}", cont.mean_occupancy),
    ]);
    report.table("staggered mixed-step arrivals, same schedule for both", &tbl);
    report.csv("series", &tbl);
    report.text(&format!(
        "\n{N_REQS} staggered requests (steps alternating {steps}/{}): continuous \
         batching serves p50 {p50_cont:.3}s vs {p50_lock:.3}s lockstep \
         ({:.2}x) at {thr_cont:.2} vs {thr_lock:.2} req/s — lanes join at \
         step boundaries and retire on their own schedules, so mixed step \
         counts share passes the lockstep key had to serialize.",
        (steps / 2).max(2),
        p50_lock / p50_cont.max(1e-9),
    ));
    report.finish()?;
    Ok(())
}
