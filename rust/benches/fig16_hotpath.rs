//! **Figure 16 (repo-original)**: host↔device transfer volume and
//! wall-clock of the device-resident hot path vs. the seed-era host-staged
//! pipeline, per policy.
//!
//! The device path measures Foresight's Eq. 5/6 drift with a fused
//! on-device MSE (4 bytes down per measured site instead of `F·P·D·4`),
//! combines CFG branches on device, steps the sampler on device over the
//! resident latent, and runs the two branches on a persistent worker
//! thread. This bench asserts the headline claims: ≥10× fewer device→host
//! bytes per step for Foresight, a wall-clock win, and final latents
//! matching the host staging to ≤1e-6 per element for a fixed seed under
//! every shipped policy (the sampler steps on device now, so agreement is
//! to f32 rounding rather than bit-exact; `fig17_resident` covers the
//! steady-state transfer A/B).

use foresight::bench_support::{first_latent_mismatch, BenchCtx};
use foresight::engine::{HotPath, Request};
use foresight::policy::build_policy;
use foresight::util::benchkit::{MdTable, Report};

const POLICIES: [(&str, &str); 3] = [
    ("Foresight (N1R2)", "foresight:n=1,r=2,gamma=0.5"),
    ("Static (N1R2)", "static:n=1,r=2"),
    ("Baseline", "none"),
];

fn run(
    ctx: &mut BenchCtx,
    hot: HotPath,
    spec: &str,
    seed: u64,
) -> anyhow::Result<foresight::engine::RunResult> {
    let engine = ctx.engine_hot("opensora-sim", "240p-2s", hot)?;
    let info = engine.model().info.clone();
    let mut policy = build_policy(spec, &info, info.steps)?;
    engine.generate(
        &Request::new("a lighthouse at dusk, waves rolling in", seed),
        policy.as_mut(),
        None,
    )
}

fn main() -> anyhow::Result<()> {
    let mut ctx = BenchCtx::new()?;
    // Warm both engines (compile caches) so timings are not compile-skewed.
    for hot in [HotPath::Device, HotPath::Host] {
        let _ = run(&mut ctx, hot, "none", 0)?;
    }

    let mut report = Report::new(
        "fig16",
        "Figure 16 — hot path: device-resident vs. host-staged transfer volume",
    );
    let mut t = MdTable::new(&[
        "Policy",
        "Mode",
        "Wall(s)",
        "d2h KiB/step",
        "h2d KiB/step",
        "d2h reduction",
        "Latents",
    ]);

    let mut foresight_reduction = 0.0f64;
    let mut foresight_speedup = 0.0f64;
    for (name, spec) in POLICIES {
        // Cross-check the engine's own byte counters against the runtime's
        // global transfer meter (single-threaded bench → exact match is
        // expected for the device run modulo concurrent-branch ordering).
        let before = ctx.runtime().transfer_stats().snapshot();
        let dev = run(&mut ctx, HotPath::Device, spec, 7)?;
        let rt_delta = ctx.runtime().transfer_stats().snapshot().delta_since(&before);
        assert_eq!(
            rt_delta.d2h_bytes, dev.stats.d2h_bytes,
            "{name}: engine d2h meter disagrees with runtime meter"
        );
        let host = run(&mut ctx, HotPath::Host, spec, 7)?;

        let mismatch = first_latent_mismatch(&dev.latents.data, &host.latents.data, 1e-6);
        assert!(
            mismatch.is_none(),
            "{name}: device and host hot paths must agree to ≤1e-6 per element \
             (first mismatch: {mismatch:?})"
        );
        let close = mismatch.is_none();
        let reduction = host.stats.d2h_bytes_per_step() / dev.stats.d2h_bytes_per_step().max(1.0);
        let speedup = host.stats.wall_s / dev.stats.wall_s;
        if spec.starts_with("foresight") {
            foresight_reduction = reduction;
            foresight_speedup = speedup;
        }
        for (mode, r) in [("device", &dev), ("host", &host)] {
            t.row(vec![
                name.into(),
                mode.into(),
                format!("{:.3}", r.stats.wall_s),
                format!("{:.2}", r.stats.d2h_bytes_per_step() / 1024.0),
                format!("{:.2}", r.stats.h2d_bytes_per_step() / 1024.0),
                if mode == "device" { format!("{reduction:.1}x") } else { "1.0x".into() },
                if close { "≤1e-6".into() } else { "DIVERGED".into() },
            ]);
        }
    }

    report.table("transfer volume and wall-clock per policy", &t);
    report.csv("series", &t);
    report.text(&format!(
        "\nForesight: {foresight_reduction:.1}x fewer device→host bytes per step, \
         {foresight_speedup:.2}x wall-clock vs. the seed hot path."
    ));
    assert!(
        foresight_reduction >= 10.0,
        "acceptance: expected ≥10x d2h reduction for Foresight, got {foresight_reduction:.1}x"
    );
    // Wall-clock is load-dependent (thread-spawn + dispatch overhead can
    // mask the saved memcpys on tiny simulated models), so a miss is
    // reported loudly rather than aborting the deterministic assertions
    // above.
    if foresight_speedup <= 1.0 {
        eprintln!(
            "[fig16] WARNING: no wall-clock win this run ({foresight_speedup:.2}x) — \
             transfer reduction held at {foresight_reduction:.1}x; rerun on an idle machine"
        );
    }
    report.finish()?;
    Ok(())
}
