//! Reproduces **Figures 12-14** (appendix): cosine similarity of
//! Spatial-DiT features (12) across conditioning/denoising steps, (13)
//! across layers at fixed steps, and (14) across steps for early / middle /
//! late layers.
//!
//! Paper shape: consecutive-step similarity is very high and rises through
//! the trajectory; consecutive-layer similarity is high but dips in late
//! layers; later layers show more step-to-step variation than early ones.

use foresight::analysis::DynamicsRecorder;
use foresight::bench_support::BenchCtx;
use foresight::engine::Request;
use foresight::model::BlockKind;
use foresight::policy::build_policy;
use foresight::util::benchkit::{MdTable, Report};

fn main() -> anyhow::Result<()> {
    let mut ctx = BenchCtx::new()?;
    let engine = ctx.engine("analysis", "240p-2s")?;
    let info = engine.model().info.clone();

    let mut rec = DynamicsRecorder::new();
    let mut pol = build_policy("none", &info, info.steps)?;
    engine.generate(
        &Request::new(
            "a drone circles a historic church on a rocky coast at golden hour",
            4,
        ),
        pol.as_mut(),
        Some(&mut rec),
    )?;

    let mut report = Report::new(
        "fig12",
        "Figures 12-14 — cosine similarity of spatial features across steps and layers",
    );

    // Fig 12/14: per-step cosine for early/middle/late probe layers
    let probes = [0, info.layers / 2, info.layers - 1];
    let mut t12 = MdTable::new(&["step", "cos(early L0)", "cos(middle)", "cos(late)"]);
    for (step, row) in &rec.step_cos {
        t12.row(vec![
            step.to_string(),
            format!("{:.5}", row.get(&(probes[0], BlockKind::Spatial)).unwrap_or(&0.0)),
            format!("{:.5}", row.get(&(probes[1], BlockKind::Spatial)).unwrap_or(&0.0)),
            format!("{:.5}", row.get(&(probes[2], BlockKind::Spatial)).unwrap_or(&0.0)),
        ]);
    }
    report.table("Fig 12/14: consecutive-step cosine per layer group", &t12);
    report.csv("step_cosine", &t12);

    // Fig 13: consecutive-layer cosine at a few steps
    let steps: Vec<usize> = rec.layer_cos.keys().copied().collect();
    let picks: Vec<usize> = [steps.len() / 4, steps.len() / 2, 3 * steps.len() / 4]
        .iter()
        .map(|&i| steps[i.min(steps.len() - 1)])
        .collect();
    let mut hdr: Vec<String> = vec!["layer".into()];
    hdr.extend(picks.iter().map(|s| format!("step {s}")));
    let mut t13 = MdTable::new(
        &hdr.iter().map(|s| Box::leak(s.clone().into_boxed_str()) as &str).collect::<Vec<_>>(),
    );
    for layer in 1..info.layers {
        let mut row = vec![layer.to_string()];
        for s in &picks {
            let v = rec.layer_cos[s].get(&(layer, BlockKind::Spatial)).copied().unwrap_or(0.0);
            row.push(format!("{v:.5}"));
        }
        t13.row(row);
    }
    report.table("Fig 13: consecutive-layer cosine at selected steps", &t13);
    report.csv("layer_cosine", &t13);

    // summary stats for EXPERIMENTS.md
    let mean_cos = |layer: usize| -> f64 {
        let v: Vec<f64> = rec
            .step_cos
            .values()
            .filter_map(|m| m.get(&(layer, BlockKind::Spatial)).copied())
            .collect();
        v.iter().sum::<f64>() / v.len().max(1) as f64
    };
    report.text(&format!(
        "\nmean step-cosine: early {:.5}, middle {:.5}, late {:.5} \
         (paper: later layers vary more → lower similarity)",
        mean_cos(probes[0]),
        mean_cos(probes[1]),
        mean_cos(probes[2])
    ));
    report.finish()?;
    Ok(())
}
