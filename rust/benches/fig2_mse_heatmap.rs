//! Reproduces **Figure 2**: (left) layer×step MSE heatmap of consecutive
//! Spatial-DiT outputs on the 28-layer `analysis` preset; (middle) the last
//! layer's MSE across resolutions; (right) across prompts.
//!
//! Paper shape: pronounced layer heterogeneity (late layers higher MSE),
//! MSE decaying over steps, and both resolution and prompt visibly shifting
//! the same layer's reuse potential.

use foresight::analysis::DynamicsRecorder;
use foresight::bench_support::{BenchCtx};
use foresight::engine::Request;
use foresight::model::BlockKind;
use foresight::policy::build_policy;
use foresight::util::benchkit::{MdTable, Report};

fn main() -> anyhow::Result<()> {
    let mut ctx = BenchCtx::new()?;
    let mut report = Report::new(
        "fig2",
        "Figure 2 — consecutive-step feature MSE: layers × steps, resolution, prompt",
    );

    // --- left: heatmap on analysis preset @ 480p ---------------------------
    let engine = ctx.engine("analysis", "480p-2s")?;
    let info = engine.model().info.clone();
    let mut rec = DynamicsRecorder::new();
    let mut pol = build_policy("none", &info, info.steps)?;
    engine.generate(
        &Request::new("a black cat darts across a rainy cobblestone alley", 1),
        pol.as_mut(),
        Some(&mut rec),
    )?;
    let hm = rec.heatmap(info.layers, BlockKind::Spatial);
    let steps: Vec<usize> = rec.step_mse.keys().copied().collect();

    let mut t = MdTable::new(
        &std::iter::once("layer".to_string())
            .chain(steps.iter().map(|s| format!("s{s}")))
            .map(|s| Box::leak(s.into_boxed_str()) as &str)
            .collect::<Vec<_>>(),
    );
    for (l, row) in hm.iter().enumerate() {
        t.row(
            std::iter::once(l.to_string())
                .chain(row.iter().map(|v| format!("{v:.3e}")))
                .collect(),
        );
    }
    report.csv("heatmap", &t);

    // compact display: early/mid/late layer-group means per step quartile
    let mut disp = MdTable::new(&["layer group", "early steps", "mid steps", "late steps"]);
    let groups = [(0, info.layers / 3, "early"), (info.layers / 3, 2 * info.layers / 3, "middle"),
                  (2 * info.layers / 3, info.layers, "late")];
    let thirds = |row: &[f64]| {
        let n = row.len();
        (
            row[..n / 3].iter().sum::<f64>() / (n / 3).max(1) as f64,
            row[n / 3..2 * n / 3].iter().sum::<f64>() / (n / 3).max(1) as f64,
            row[2 * n / 3..].iter().sum::<f64>() / (n - 2 * n / 3).max(1) as f64,
        )
    };
    let mut late_layer_mean = 0.0;
    let mut early_layer_mean = 0.0;
    for (lo, hi, name) in groups {
        let mut acc = (0.0, 0.0, 0.0);
        for l in lo..hi {
            let (a, b, c) = thirds(&hm[l]);
            acc = (acc.0 + a, acc.1 + b, acc.2 + c);
        }
        let n = (hi - lo) as f64;
        if name == "late" {
            late_layer_mean = (acc.0 + acc.1 + acc.2) / (3.0 * n);
        }
        if name == "early" {
            early_layer_mean = (acc.0 + acc.1 + acc.2) / (3.0 * n);
        }
        disp.row(vec![
            name.into(),
            format!("{:.3e}", acc.0 / n),
            format!("{:.3e}", acc.1 / n),
            format!("{:.3e}", acc.2 / n),
        ]);
    }
    report.table("heatmap summary (full heatmap in fig2_heatmap.csv)", &disp);
    report.text(&format!(
        "layer heterogeneity: late/early layer MSE ratio = {:.2} (paper: late layers \
         change most)",
        late_layer_mean / early_layer_mean.max(1e-12)
    ));

    // --- middle: last layer across resolutions -----------------------------
    let last = info.layers - 1;
    let mut tm = MdTable::new(&["resolution", "mean MSE (last layer, spatial)"]);
    for bucket in ["240p-2s", "480p-2s", "720p-2s"] {
        let engine = ctx.engine("analysis", bucket)?;
        let mut rec = DynamicsRecorder::new();
        let mut pol = build_policy("none", &info, info.steps)?;
        engine.generate(
            &Request::new("a black cat darts across a rainy cobblestone alley", 1),
            pol.as_mut(),
            Some(&mut rec),
        )?;
        tm.row(vec![bucket.into(), format!("{:.4e}", rec.mean_step_mse(last, BlockKind::Spatial))]);
    }
    report.table("middle: resolution dependence (last layer)", &tm);
    report.csv("resolution", &tm);

    // --- right: last layer across prompts ----------------------------------
    let engine = ctx.engine("analysis", "240p-2s")?;
    let mut tp = MdTable::new(&["prompt", "motion", "mean MSE (last layer, spatial)"]);
    for prompt in [
        "a serene still painting of a quiet library, calm soft light",
        "a lighthouse on a rocky coast at dusk, gentle waves",
        "a dog running jumping and darting fast as waves crash in a storm",
        "drone racing rapidly through exploding fireworks, spinning wildly",
    ] {
        let mut rec = DynamicsRecorder::new();
        let mut pol = build_policy("none", &info, info.steps)?;
        engine.generate(&Request::new(prompt, 2), pol.as_mut(), Some(&mut rec))?;
        tp.row(vec![
            prompt[..32.min(prompt.len())].into(),
            format!("{:.2}", foresight::workload::motion_complexity(prompt)),
            format!("{:.4e}", rec.mean_step_mse(last, BlockKind::Spatial)),
        ]);
    }
    report.table("right: prompt dependence (last layer)", &tp);
    report.csv("prompts", &tp);

    report.finish()?;
    Ok(())
}
