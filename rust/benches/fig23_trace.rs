//! **Figure 23 (repo-original)**: the per-step tracer's cost and safety
//! contract against the live server.
//!
//! Four properties, end to end on the wire:
//!
//! * **tracing-off ≈ baseline** — the tracer is always compiled in; with
//!   recording disabled the `trace_events` ledger must not move at all
//!   across a batch of requests, and the measured walls are the baseline.
//! * **tracing-on bounded overhead** — the same batch with recording
//!   enabled (plus `"trace": true` timelines) stays within a small
//!   multiple of the baseline wall: per-event cost is one atomic `seq`,
//!   one clock read and one `try_lock` push.
//! * **drops counted, never blocked** — a flash-crowd emission schedule
//!   ([`foresight::util::loadgen`]) against a deliberately tiny ring
//!   must satisfy `drops == emitted_total - resident` exactly: every
//!   event past capacity is counted and dropped, no producer ever waits.
//! * **Chrome export round-trips** — the wire drain wrapped in the
//!   [`foresight::trace::chrome::document`] envelope re-parses with
//!   [`foresight::util::json`], timestamps are monotonic per thread (in
//!   `seq` order), and every traced request contributes exactly one
//!   complete async span (`ph:"b"`/`ph:"e"` pair).
//!
//! `FORESIGHT_BENCH_STEPS` overrides the step count (CI smoke mode).
//! Exits cleanly with a SKIP note when the AOT artifacts are absent.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use std::time::Instant;

use foresight::config::Manifest;
use foresight::runtime::DevicePool;
use foresight::server::{Client, EngineRegistry, Server, ServerConfig};
use foresight::trace::{self, chrome, Payload, Tracer};
use foresight::util::benchkit::{MdTable, Report};
use foresight::util::json::{self, Json};
use foresight::util::loadgen;
use foresight::util::stats;

const MODEL: &str = "opensora-sim";
const BUCKET: &str = "240p-2s";
const POLICY: &str = "foresight";
/// Requests per timing phase.
const RUNS: usize = 4;
/// Per-shard ring capacity for the drop phase — tiny on purpose.
const TINY_RING: usize = 4;

fn bench_steps() -> usize {
    std::env::var("FORESIGHT_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10)
        .max(4)
}

fn gen_req(prompt: &str, seed: u64, steps: usize, traced: bool) -> Json {
    let mut fields = vec![
        ("op", Json::str("generate")),
        ("model", Json::str(MODEL)),
        ("bucket", Json::str(BUCKET)),
        ("policy", Json::str(POLICY)),
        ("prompt", Json::str(prompt)),
        ("seed", Json::num(seed as f64)),
        ("steps", Json::num(steps as f64)),
    ];
    if traced {
        fields.push(("trace", Json::Bool(true)));
    }
    Json::obj(fields)
}

fn get_f64(j: &Json, k: &str) -> f64 {
    j.get(k)
        .and_then(|v| v.as_f64())
        .unwrap_or_else(|| panic!("missing {k}: {j}"))
}

fn get_str<'a>(j: &'a Json, k: &str) -> &'a str {
    j.get(k)
        .and_then(|v| v.as_str())
        .unwrap_or_else(|| panic!("missing {k}: {j}"))
}

fn stats_op(c: &mut Client) -> Json {
    c.call(&Json::obj(vec![("op", Json::str("stats"))]))
        .expect("stats op")
}

fn main() -> anyhow::Result<()> {
    let manifest = match Manifest::load(&Manifest::default_root()) {
        Ok(m) => m,
        Err(e) => {
            println!("[fig23] SKIP: artifacts unavailable ({e:#}); run `make artifacts`");
            return Ok(());
        }
    };
    let steps = bench_steps();

    let pool = Arc::new(DevicePool::cpu(1)?);
    let registry = Arc::new(EngineRegistry::load_pool(
        pool,
        &manifest,
        &[(MODEL.to_string(), BUCKET.to_string())],
    )?);
    let server = Server::start(
        registry,
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: 1,
            devices: 1,
            ..ServerConfig::default()
        },
    )?;
    let addr = server.addr();
    let mut c = Client::connect(&addr)?;

    // Warm pass so compile/cache effects hit neither timing phase.
    let r = c.call(&gen_req("warmup", 1, steps, false))?;
    assert_eq!(get_str(&r, "status"), "ok", "warmup failed: {r}");

    // --- phase: tracing off (the baseline) ------------------------------
    trace::global().enable(false);
    let ev_before = get_f64(&stats_op(&mut c), "trace_events");
    let mut wall_off = Vec::new();
    for i in 0..RUNS {
        let t0 = Instant::now();
        let r = c.call(&gen_req(&format!("off {i}"), 10 + i as u64, steps, false))?;
        wall_off.push(t0.elapsed().as_secs_f64());
        assert_eq!(get_str(&r, "status"), "ok", "off {i}: {r}");
    }
    let ev_after_off = get_f64(&stats_op(&mut c), "trace_events");
    assert_eq!(
        ev_before, ev_after_off,
        "a disabled tracer must record nothing (tracing-off IS the baseline)"
    );

    // --- phase: tracing on ----------------------------------------------
    let ten = c.call(&Json::obj(vec![
        ("op", Json::str("trace")),
        ("enable", Json::Bool(true)),
    ]))?;
    assert_eq!(get_str(&ten, "status"), "ok", "{ten}");
    assert_eq!(ten.get("enabled").and_then(|v| v.as_bool()), Some(true), "{ten}");
    let drain_floor = get_f64(&ten, "next") as u64;

    let mut wall_on = Vec::new();
    for i in 0..RUNS {
        let t0 = Instant::now();
        let r = c.call(&gen_req(&format!("on {i}"), 20 + i as u64, steps, true))?;
        wall_on.push(t0.elapsed().as_secs_f64());
        assert_eq!(get_str(&r, "status"), "ok", "on {i}: {r}");
        assert!(
            r.get("reuse_timeline").and_then(|v| v.as_arr()).is_some_and(|a| !a.is_empty()),
            "trace:true response lost its timeline: {r}"
        );
    }
    let ev_after_on = get_f64(&stats_op(&mut c), "trace_events");
    assert!(
        ev_after_on > ev_after_off,
        "enabled tracer recorded nothing ({ev_after_off} -> {ev_after_on})"
    );

    let mean_off = stats::mean(&wall_off);
    let mean_on = stats::mean(&wall_on);
    // Per-event cost is nanoseconds against a multi-millisecond request;
    // the bound is deliberately loose for CI noise — the property is that
    // tracing cannot multiply the wall, not a precise ratio.
    assert!(
        mean_on <= mean_off * 3.0 + 0.25,
        "tracing-on wall {mean_on:.4}s not bounded vs baseline {mean_off:.4}s"
    );

    // --- phase: Chrome export round-trip --------------------------------
    let d = c.call(&Json::obj(vec![
        ("op", Json::str("trace")),
        ("since", Json::num(drain_floor as f64)),
    ]))?;
    assert_eq!(get_str(&d, "status"), "ok", "{d}");
    let events = d.get("events").and_then(|v| v.as_arr()).expect("events").to_vec();
    assert!(!events.is_empty(), "traced phase drained no events");

    let text = chrome::document(events.clone()).to_string();
    let parsed = json::parse(&text).expect("chrome trace JSON must re-parse via util::json");
    let evs = parsed
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .expect("traceEvents array")
        .to_vec();
    assert_eq!(evs.len(), events.len(), "envelope dropped events");

    // Timestamps monotonic per thread, taken in seq order.
    let mut by_tid: BTreeMap<u64, Vec<(u64, u64)>> = BTreeMap::new();
    for e in &evs {
        let tid = get_f64(e, "tid") as u64;
        let seq = get_f64(e, "seq") as u64;
        let ts = get_f64(e, "ts") as u64;
        by_tid.entry(tid).or_default().push((seq, ts));
    }
    for (tid, mut sts) in by_tid {
        sts.sort_unstable();
        assert!(
            sts.windows(2).all(|w| w[0].1 <= w[1].1),
            "non-monotonic timestamps on thread {tid}"
        );
    }

    // Exactly one complete async span per traced request.
    let mut begin_ids = BTreeSet::new();
    let mut end_ids = BTreeSet::new();
    for e in &evs {
        match e.get("ph").and_then(|p| p.as_str()) {
            Some("b") => {
                let id = get_f64(e, "id") as u64;
                assert!(id != 0, "span begin without a trace id: {e}");
                assert!(begin_ids.insert(id), "duplicate span begin for {id}");
            }
            Some("e") => {
                let id = get_f64(e, "id") as u64;
                assert!(end_ids.insert(id), "duplicate span end for {id}");
            }
            _ => {}
        }
    }
    assert_eq!(begin_ids, end_ids, "unpaired request spans");
    assert_eq!(begin_ids.len(), RUNS, "one span per traced request");
    assert!(
        evs.iter().any(|e| e.get("name").and_then(|n| n.as_str()) == Some("policy")),
        "no per-step policy events in the drain"
    );
    assert!(
        evs.iter()
            .any(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X")),
        "no complete fused-pass events in the drain"
    );

    // --- phase: flash-crowd drops against a tiny ring --------------------
    // A dedicated tracer with a deliberately tiny per-shard ring: the
    // flash crowd must overflow it, and every overflow is *counted*, not
    // blocked on — the exact invariant is drops == emitted - resident.
    let tiny = Tracer::new(true, TINY_RING);
    let arrivals = loadgen::flash_crowd(33, 0.3, 50.0, 0.1, 0.15, 1000.0, 1);
    let records_per_arrival = 10u64;
    let t0 = Instant::now();
    loadgen::replay(&arrivals, |_, _| {
        let id = tiny.next_trace_id();
        tiny.record(id, 0, Payload::Begin);
        for s in 0..records_per_arrival - 2 {
            tiny.record(
                id,
                0,
                Payload::Policy {
                    step: s as u32,
                    branch: 0,
                    site: 0,
                    reuse: s % 2 == 0,
                    predict: false,
                    mse: 0.1,
                    lambda: 0.2,
                },
            );
        }
        tiny.record(id, 0, Payload::End { ok: true });
    });
    let flash_wall = t0.elapsed().as_secs_f64();
    let total_records = arrivals.len() as u64 * records_per_arrival;
    let resident = tiny.drain(0).events.len() as u64;
    let drops = tiny.drops_total();
    assert!(drops > 0, "the flash crowd must overflow a {TINY_RING}-slot ring");
    assert_eq!(
        drops,
        total_records - resident,
        "drop accounting must close exactly: {total_records} emitted, {resident} resident"
    );
    assert!(
        flash_wall < 30.0,
        "emission blocked under overflow ({flash_wall:.1}s for a 0.3s schedule)"
    );

    let trace_drops_srv = get_f64(&stats_op(&mut c), "trace_drops");
    assert!(trace_drops_srv >= 0.0);
    server.shutdown();

    // --- report ----------------------------------------------------------
    let mut report = Report::new(
        "fig23_trace",
        "Figure 23 — structured tracing: overhead, drop safety, Chrome export",
    );
    report.config("model", Json::str(MODEL));
    report.config("bucket", Json::str(BUCKET));
    report.config("policy", Json::str(POLICY));
    report.config("steps", Json::num(steps as f64));
    report.config("runs", Json::num(RUNS as f64));
    report.config("tiny_ring", Json::num(TINY_RING as f64));

    let mut tbl = MdTable::new(&["Phase", "Requests", "Mean wall (s)", "p99 wall (s)"]);
    tbl.row(vec![
        "tracing off (baseline)".into(),
        format!("{RUNS}"),
        format!("{mean_off:.4}"),
        format!("{:.4}", stats::percentile(&wall_off, 99.0)),
    ]);
    tbl.row(vec![
        "tracing on (+timeline)".into(),
        format!("{RUNS}"),
        format!("{mean_on:.4}"),
        format!("{:.4}", stats::percentile(&wall_on, 99.0)),
    ]);
    report.table("Request wall with the tracer off vs on", &tbl);
    report.csv("overhead", &tbl);

    report.metric("wall_off_mean_s", mean_off);
    report.metric("wall_on_mean_s", mean_on);
    report.metric("overhead_ratio", if mean_off > 0.0 { mean_on / mean_off } else { 0.0 });
    report.metric("trace_events", ev_after_on);
    report.metric("trace_drops_server", trace_drops_srv);
    report.metric("chrome_events", evs.len() as f64);
    report.metric("spans", begin_ids.len() as f64);
    report.metric("flash_records", total_records as f64);
    report.metric("flash_drops", drops as f64);
    report.metric("flash_resident", resident as f64);

    report.text(&format!(
        "\nA disabled tracer recorded zero events across {RUNS} requests; enabled, \
         the wall stayed within 3x+0.25s of baseline ({mean_on:.4}s vs {mean_off:.4}s). \
         The {}-event drain re-parsed as Chrome trace JSON with per-thread monotonic \
         timestamps and exactly one complete span per traced request. Under a \
         flash-crowd schedule a {TINY_RING}-slot ring dropped {drops} of {total_records} \
         events with exact accounting (drops == emitted - resident) and no producer \
         ever blocked.",
        evs.len()
    ));
    report.finish()?;
    Ok(())
}
