//! **Figure 21 (repo-original)**: multi-device sharded serving — N runtime
//! replicas behind the continuous scheduler.
//!
//! Replays a fig20-style staggered arrival trace against N ∈ {1, 2, 4}
//! independent runtime replicas (one PJRT client + executable caches +
//! transfer meter each — exactly what `--devices N` builds in the server).
//! Offered load scales with the fleet: N devices see B·N requests at 1/N
//! the mean arrival gap, so per-device pressure is held constant while
//! aggregate throughput should scale near-linearly.
//!
//! As in fig20, arrival times are virtual (seeded, identical discipline at
//! every N) and execution costs are real measured walls charged to
//! per-device virtual clocks, so the comparison is deterministic up to CPU
//! noise. Routing in the replay is least-loaded — with a single cohort key
//! and uniform traffic, the fixed point of the server's
//! cohort-affinity-then-least-loaded rule.
//!
//! Asserts the sharding contract:
//!
//! * **(a) scaling** — throughput at N devices ≥ 0.70·N× the N=1
//!   throughput on the matching B·N trace;
//! * **(b) no single-device regression** — p50 latency at N=1 is no worse
//!   than the pre-change continuous scheduler on the identical trace
//!   (same discipline, small noise tolerance);
//! * **(c) placement-independent latents** — every request served by any
//!   replica matches its standalone oracle to ≤1e-6, including a session
//!   force-migrated between replicas mid-request (a work steal);
//! * **(d) metered steal** — the migrated request's `RunStats` charge
//!   exactly one extra lane download on the source and one extra lane
//!   upload on the target (`latent_elems·4` bytes, one call each way)
//!   versus its never-migrated oracle.
//!
//! `FORESIGHT_BENCH_STEPS` overrides the step count (CI smoke mode).
//! Exits cleanly with a SKIP note when the AOT artifacts are absent.

use std::sync::Arc;
use std::time::Instant;

use foresight::bench_support::first_latent_mismatch;
use foresight::config::Manifest;
use foresight::engine::{step_many_refs, Engine, HotPath, Request, RunResult, Session};
use foresight::policy::{build_policy, ReusePolicy};
use foresight::runtime::DevicePool;
use foresight::util::benchkit::{MdTable, Report};
use foresight::util::json::Json;
use foresight::util::prng::Rng;
use foresight::util::stats;

use foresight::model::LoadedModel;

const MODEL: (&str, &str) = ("opensora-sim", "240p-2s");
const POLICY: &str = "foresight:n=1,r=2,gamma=0.5";
const MAX_BATCH: usize = 4;
/// Requests per device — each N-device trace replays B·N requests.
const B: usize = 4;
const FLEETS: [usize; 3] = [1, 2, 4];
const PROMPTS: [&str; 8] = [
    "a paper lantern drifting over a midnight lake",
    "a fox darting through fresh snow at dawn",
    "waves crashing against a basalt cliff in a storm",
    "a quiet greenhouse, sunlight through fogged glass",
    "a tram crossing a rainy neon intersection",
    "dust motes in a sunbeam over an old library",
    "a glacier calving into a mirror-still fjord",
    "origami cranes unfolding in reverse slow motion",
];

fn bench_steps() -> usize {
    std::env::var("FORESIGHT_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10)
        .max(4)
}

/// The shared request list: every fleet size replays a prefix of the same
/// B·4 requests, so one oracle set covers all three traces.
fn requests(steps: usize) -> Vec<Request> {
    (0..B * *FLEETS.last().unwrap())
        .map(|i| {
            let mut r = Request::new(PROMPTS[i % PROMPTS.len()], 700 + i as u64);
            r.steps = Some(steps);
            r
        })
        .collect()
}

fn policy_for(engine: &Engine, req: &Request) -> anyhow::Result<Box<dyn ReusePolicy>> {
    let info = &engine.model().info;
    build_policy(POLICY, info, req.steps.unwrap_or(info.steps))
}

fn standalone(engine: &Engine, req: &Request) -> anyhow::Result<RunResult> {
    let mut pol = policy_for(engine, req)?;
    engine.generate(req, pol.as_mut(), None)
}

struct SimOutcome {
    latencies: Vec<f64>,
    makespan: f64,
    results: Vec<Option<RunResult>>,
}

/// Event-driven replay of one device's continuous scheduler (fig20's
/// discipline): admissions at step boundaries, eager retirement, real
/// measured pass walls on a virtual arrival clock. `reqs`/`arrivals` are
/// the subset routed to this device; latencies/results land at `idx`.
fn device_sim(
    engine: &Engine,
    reqs: &[(usize, Request, f64)], // (global idx, request, arrival)
    latencies: &mut [f64],
    results: &mut [Option<RunResult>],
) -> anyhow::Result<f64> {
    let mut vnow = 0.0f64;
    let mut next = 0usize;
    let mut lanes: Vec<(Session<'static>, f64, usize)> = Vec::new();
    let mut last_done = 0.0f64;

    while next < reqs.len() || !lanes.is_empty() {
        if lanes.is_empty() && next < reqs.len() && reqs[next].2 > vnow {
            vnow = reqs[next].2;
        }
        while next < reqs.len() && reqs[next].2 <= vnow && lanes.len() < MAX_BATCH {
            let t0 = Instant::now();
            let pol = policy_for(engine, &reqs[next].1)?;
            let s = engine.admit(&reqs[next].1, pol)?;
            vnow += t0.elapsed().as_secs_f64();
            lanes.push((s, reqs[next].2, reqs[next].0));
            next += 1;
        }
        let t0 = Instant::now();
        {
            let mut refs: Vec<&mut Session> = lanes.iter_mut().map(|(s, _, _)| s).collect();
            step_many_refs(&mut refs)?;
        }
        vnow += t0.elapsed().as_secs_f64();
        let mut i = 0;
        while i < lanes.len() {
            if lanes[i].0.is_done() {
                let (s, arr, idx) = lanes.remove(i);
                let t0 = Instant::now();
                let r = s.finish()?;
                vnow += t0.elapsed().as_secs_f64();
                latencies[idx] = vnow - arr;
                results[idx] = Some(r);
                last_done = vnow;
            } else {
                i += 1;
            }
        }
    }
    Ok(last_done)
}

/// Sharded replay: route each arrival to the least-loaded replica (fewest
/// outstanding requests, ties by ordinal — the uniform-traffic fixed point
/// of the server's routing), then run every device's continuous replay on
/// its own virtual clock. Makespan is the latest per-device finish.
fn sharded_sim(
    engines: &[Arc<Engine>],
    reqs: &[Request],
    arrivals: &[f64],
    est_service: f64,
) -> anyhow::Result<SimOutcome> {
    let n = engines.len();
    let mut per_dev: Vec<Vec<(usize, Request, f64)>> = vec![Vec::new(); n];
    // Outstanding-request estimate per device at each arrival, from the
    // calibrated standalone service time.
    let mut busy_until: Vec<Vec<f64>> = vec![Vec::new(); n];
    for (i, (req, &arr)) in reqs.iter().zip(arrivals).enumerate() {
        let load = |d: usize| busy_until[d].iter().filter(|&&t| t > arr).count();
        let dev = (0..n).min_by_key(|&d| (load(d), d)).unwrap();
        busy_until[dev].push(arr + est_service);
        per_dev[dev].push((i, req.clone(), arr));
    }

    let mut latencies = vec![0.0f64; reqs.len()];
    let mut results: Vec<Option<RunResult>> = (0..reqs.len()).map(|_| None).collect();
    let mut last_done = 0.0f64;
    for (d, engine) in engines.iter().enumerate() {
        let done = device_sim(engine, &per_dev[d], &mut latencies, &mut results)?;
        last_done = last_done.max(done);
    }
    Ok(SimOutcome { latencies, makespan: last_done - arrivals[0], results })
}

/// Seeded Poisson-ish arrivals: B·n requests at mean gap `base_gap / n`
/// (offered load scales with the fleet).
fn arrivals_for(n: usize, count: usize, base_gap: f64) -> Vec<f64> {
    let mut rng = Rng::from_seed_and_label(11, &format!("fig21-arrivals-n{n}"));
    let mut out = Vec::with_capacity(count);
    let mut t = 0.0f64;
    for _ in 0..count {
        let u = rng.next_f64().clamp(1e-6, 1.0 - 1e-6);
        t += -(base_gap / n as f64) * u.ln();
        out.push(t);
    }
    out
}

fn main() -> anyhow::Result<()> {
    let manifest = match Manifest::load(&Manifest::default_root()) {
        Ok(m) => m,
        Err(e) => {
            println!("[fig21] SKIP: artifacts unavailable ({e:#}); run `make artifacts`");
            return Ok(());
        }
    };
    let steps = bench_steps();
    let n_max = *FLEETS.last().unwrap();

    // One independent runtime replica per device — the same construction
    // `--devices N` performs in the server.
    let pool = DevicePool::cpu(n_max)?;
    let mut engines: Vec<Arc<Engine>> = Vec::with_capacity(n_max);
    for rt in pool.devices() {
        let lm = Arc::new(LoadedModel::load(rt.clone(), &manifest, MODEL.0, MODEL.1)?);
        engines.push(Arc::new(Engine::with_hot_path(lm, manifest.schedule, HotPath::Device)));
    }

    let reqs = requests(steps);

    // Standalone oracles on device 0 (identical weights on every replica
    // ⇒ one oracle set covers all placements), plus wall calibration.
    let mut oracles = Vec::with_capacity(reqs.len());
    for r in &reqs {
        oracles.push(standalone(&engines[0], r)?);
    }
    let step_wall = {
        let s = &oracles[0].stats;
        s.wall_s / s.per_step_s.len().max(1) as f64
    };
    let base_gap = 1.5 * step_wall;
    let est_service = steps as f64 * step_wall;

    // Warm every replica's fused-shape caches (cohort steps at each
    // occupancy), then measure. Two passes per fleet size, as in fig20.
    let mut outcomes: Vec<(usize, SimOutcome)> = Vec::new();
    for &n in &FLEETS {
        let sub = &reqs[..B * n];
        let arrivals = arrivals_for(n, sub.len(), base_gap);
        let _ = sharded_sim(&engines[..n], sub, &arrivals, est_service)?;
        let out = sharded_sim(&engines[..n], sub, &arrivals, est_service)?;
        outcomes.push((n, out));
    }

    // Baseline: the pre-change (single-device) continuous scheduler on the
    // identical N=1 trace — fig20's discipline verbatim.
    let base = {
        let sub = &reqs[..B];
        let arrivals = arrivals_for(1, sub.len(), base_gap);
        let _ = sharded_sim(&engines[..1], sub, &arrivals, est_service)?;
        sharded_sim(&engines[..1], sub, &arrivals, est_service)?
    };

    // --- acceptance (c): latents match the standalone oracle regardless
    // of which replica served the request.
    for (n, out) in &outcomes {
        for (i, got) in out.results.iter().enumerate() {
            let got = got.as_ref().expect("sharded sim finished every request");
            let want = &oracles[i];
            let mismatch = first_latent_mismatch(&got.latents.data, &want.latents.data, 1e-6);
            assert!(
                mismatch.is_none(),
                "n={n} request {i}: sharded latents diverged from standalone \
                 (first mismatch: {mismatch:?})"
            );
            assert_eq!(
                (got.stats.computed_units, got.stats.reused_units),
                (want.stats.computed_units, want.stats.reused_units),
                "n={n} request {i}: decisions diverged"
            );
        }
    }

    // --- acceptance (a): near-linear throughput scaling at offered load
    // B·N (per-device virtual clocks make this deterministic up to noise).
    let thr: Vec<(usize, f64)> = outcomes
        .iter()
        .map(|(n, o)| (*n, (B * n) as f64 / o.makespan))
        .collect();
    let thr1 = thr[0].1;
    for &(n, t) in &thr {
        assert!(
            t >= 0.70 * n as f64 * thr1,
            "n={n}: throughput {t:.2}/s below 0.70x linear scaling from {thr1:.2}/s"
        );
    }

    // --- acceptance (b): p50 at N=1 no worse than the pre-change
    // scheduler on the identical trace.
    let p50_1 = stats::percentile(&outcomes[0].1.latencies, 50.0);
    let p50_base = stats::percentile(&base.latencies, 50.0);
    assert!(
        p50_1 <= p50_base * 1.10 + 0.05,
        "sharded n=1 p50 {p50_1:.3}s worse than single-device baseline {p50_base:.3}s"
    );

    // --- acceptance (c)+(d): a forced mid-request steal. The session
    // starts on replica 0, migrates to replica 1 at the halfway boundary,
    // and must finish bit-compatible with its never-migrated oracle while
    // charging exactly one lane download + one lane upload.
    let mreq = {
        let mut r = Request::new("a crane folding itself from paper", 991);
        r.steps = Some(steps);
        r
    };
    let oracle_m = standalone(&engines[0], &mreq)?;
    let lane_bytes = {
        let m = engines[0].model();
        let [f, p, _] = m.state_dims();
        let [_, _, c_lat] = m.latent_dims();
        (f * p * c_lat * 4) as u64
    };
    let pol = policy_for(&engines[0], &mreq)?;
    let mut sess = engines[0].admit(&mreq, pol)?;
    for _ in 0..steps / 2 {
        sess.step(None)?;
    }
    sess.migrate(&engines[1])?;
    while !sess.is_done() {
        sess.step(None)?;
    }
    let got = sess.finish()?;
    let mismatch = first_latent_mismatch(&got.latents.data, &oracle_m.latents.data, 1e-6);
    assert!(
        mismatch.is_none(),
        "migrated session diverged from never-migrated oracle (first mismatch: {mismatch:?})"
    );
    assert_eq!(
        (got.stats.computed_units, got.stats.reused_units),
        (oracle_m.stats.computed_units, oracle_m.stats.reused_units),
        "migrated session: decisions diverged"
    );
    assert_eq!(
        got.stats.d2h_bytes,
        oracle_m.stats.d2h_bytes + lane_bytes,
        "steal download bytes != one metered lane"
    );
    assert_eq!(got.stats.d2h_calls, oracle_m.stats.d2h_calls + 1, "steal download calls != 1");
    assert_eq!(
        got.stats.h2d_bytes,
        oracle_m.stats.h2d_bytes + lane_bytes,
        "steal upload bytes != one metered lane"
    );
    assert_eq!(got.stats.h2d_calls, oracle_m.stats.h2d_calls + 1, "steal upload calls != 1");

    // --- report -------------------------------------------------------
    let mut report = Report::new(
        "fig21",
        "Figure 21 — multi-device sharded serving: throughput scaling and steal correctness",
    );
    report.config("model", Json::str(MODEL.0));
    report.config("bucket", Json::str(MODEL.1));
    report.config("policy", Json::str(POLICY));
    report.config("steps", Json::num(steps as f64));
    report.config("requests_per_device", Json::num(B as f64));
    report.config("max_batch", Json::num(MAX_BATCH as f64));
    report.config(
        "fleets",
        Json::Arr(FLEETS.iter().map(|&n| Json::num(n as f64)).collect()),
    );

    let mut tbl = MdTable::new(&[
        "Devices",
        "Requests",
        "Makespan(s)",
        "Req/s",
        "Scaling vs N=1",
        "p50 lat(s)",
        "p99 lat(s)",
    ]);
    for (n, out) in &outcomes {
        let t = (B * n) as f64 / out.makespan;
        tbl.row(vec![
            format!("{n}"),
            format!("{}", B * n),
            format!("{:.3}", out.makespan),
            format!("{t:.2}"),
            format!("{:.2}x", t / thr1.max(1e-9)),
            format!("{:.3}", stats::percentile(&out.latencies, 50.0)),
            format!("{:.3}", stats::percentile(&out.latencies, 99.0)),
        ]);
    }
    report.table("B·N staggered arrivals per fleet size, least-loaded routing", &tbl);
    report.csv("scaling", &tbl);

    let (n_top, out_top) = outcomes.last().unwrap();
    report.metric("wall_s", out_top.makespan);
    report.metric("throughput_rps", (B * n_top) as f64 / out_top.makespan);
    report.metric("p50_s", stats::percentile(&out_top.latencies, 50.0));
    report.metric("p99_s", stats::percentile(&out_top.latencies, 99.0));
    for (n, out) in &outcomes {
        report.metric(&format!("throughput_rps_n{n}"), (B * n) as f64 / out.makespan);
        report.metric(&format!("p50_s_n{n}"), stats::percentile(&out.latencies, 50.0));
    }
    report.metric("baseline_p50_s", p50_base);
    report.metric("steal_lane_bytes", lane_bytes as f64);

    report.text(&format!(
        "\n{} replicas serve {}x the N=1 offered load at {:.2}x the N=1 \
         throughput; every request matches its standalone oracle to ≤1e-6 \
         regardless of serving replica, and a forced mid-request steal \
         charges exactly one lane down + one lane up ({lane_bytes} bytes \
         each way) while staying bit-compatible.",
        n_top,
        n_top,
        ((B * n_top) as f64 / out_top.makespan) / thr1.max(1e-9),
    ));
    report.finish()?;
    Ok(())
}
