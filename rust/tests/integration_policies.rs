//! Property-based tests on coordinator invariants (DESIGN.md §7), driven
//! through thousands of synthetic decision trajectories with the in-tree
//! proptest-lite harness — no PJRT runtime needed.

use foresight::autotune::{GridSpec, Knobs};
use foresight::cache::Unit;
use foresight::config::{SamplerKind, ScheduleConfig};
use foresight::model::{BlockKind, SubUnit};
use foresight::policy::{
    build_policy, Action, Foresight, Granularity, NoReuse, Pab, ReusePolicy, Site, StaticReuse,
};
use foresight::sampler;
use foresight::util::json::{self, Json};
use foresight::util::proptest::{prop_assert, proptest_cases, Gen};
use foresight::workload;

fn coarse_site(layer: usize, kind: BlockKind, branch: usize) -> Site {
    Site { layer, kind, unit: Unit::Block, branch }
}

/// Drive a coarse policy through a synthetic trajectory of per-site MSEs,
/// returning the decision sequence. `mse_fn(step, layer)` defines feature
/// dynamics.
fn drive_coarse(
    policy: &mut dyn ReusePolicy,
    layers: usize,
    steps: usize,
    mse_fn: impl Fn(usize, usize) -> f64,
) -> Vec<Vec<bool>> {
    policy.begin_request(layers, steps);
    let mut out = Vec::new();
    for step in 0..steps {
        let mut row = Vec::new();
        for layer in 0..layers {
            for kind in BlockKind::ALL {
                let site = coarse_site(layer, kind, 0);
                let a = policy.action(step, site);
                row.push(a.is_reuse());
                if let Action::Compute { measure: true, .. } = a {
                    policy.observe_mse(step, site, mse_fn(step, layer));
                }
            }
        }
        out.push(row);
    }
    out
}

#[test]
fn prop_policies_are_deterministic() {
    proptest_cases(60, |g: &mut Gen| {
        let layers = g.usize_in(1..=8);
        let steps = g.usize_in(8..=60);
        let spec = *g.pick(&["static", "foresight", "delta-dit", "tgate", "pab"]);
        let seed_mse: Vec<f64> = (0..steps * layers)
            .map(|i| g.f64_in(0.0, 1.0) + i as f64 * 1e-9)
            .collect();
        let info = fake_model(layers);
        let mse = |step: usize, layer: usize| seed_mse[step * layers + layer];

        let mut p1 = build_policy(spec, &info, steps).unwrap();
        let mut p2 = build_policy(spec, &info, steps).unwrap();
        let (d1, d2);
        if p1.granularity() == Granularity::Coarse {
            d1 = drive_coarse(p1.as_mut(), layers, steps, mse);
            d2 = drive_coarse(p2.as_mut(), layers, steps, mse);
        } else {
            d1 = drive_fine(p1.as_mut(), layers, steps);
            d2 = drive_fine(p2.as_mut(), layers, steps);
        }
        prop_assert(d1 == d2, format!("{spec}: nondeterministic decisions"));
    });
}

fn drive_fine(policy: &mut dyn ReusePolicy, layers: usize, steps: usize) -> Vec<Vec<bool>> {
    policy.begin_request(layers, steps);
    let mut out = Vec::new();
    for step in 0..steps {
        let mut row = Vec::new();
        for layer in 0..layers {
            for kind in BlockKind::ALL {
                for sub in SubUnit::ALL {
                    let site = Site { layer, kind, unit: Unit::Sub(sub), branch: 0 };
                    row.push(policy.action(step, site).is_reuse());
                }
            }
        }
        out.push(row);
    }
    out
}

fn fake_model(layers: usize) -> foresight::config::ModelInfo {
    foresight::config::ModelInfo {
        name: "prop".into(),
        layers,
        d_model: 32,
        n_heads: 4,
        d_text: 16,
        text_len: 8,
        latent_channels: 8,
        mlp_ratio: 4,
        t_freq_dim: 64,
        sampler: SamplerKind::Rflow,
        steps: 30,
        cfg_scale: 7.5,
        weights_dir: "w".into(),
        piece_params: Default::default(),
        buckets: Default::default(),
    }
}

#[test]
fn prop_foresight_never_reuses_in_warmup_and_refresh() {
    proptest_cases(80, |g: &mut Gen| {
        let layers = g.usize_in(1..=6);
        let steps = g.usize_in(10..=80);
        let r = g.usize_in(2..=5);
        let gamma = g.f64_in(0.1, 2.0);
        let wf = g.f64_in(0.05, 0.4);
        let mut p = Foresight::new(r - 1, r, gamma, wf).unwrap();
        let decisions = drive_coarse(&mut p, layers, steps, |s, l| {
            1.0 / (1.0 + s as f64 + l as f64)
        });
        let w = p.warmup_steps();
        for (step, row) in decisions.iter().enumerate() {
            if step < w {
                prop_assert(
                    row.iter().all(|&x| !x),
                    format!("reuse during warmup step {step} (W={w})"),
                );
            } else if (step - w) % r == 0 {
                prop_assert(
                    row.iter().all(|&x| !x),
                    format!("reuse on refresh step {step}"),
                );
            }
        }
    });
}

#[test]
fn prop_foresight_reuse_monotone_in_gamma() {
    proptest_cases(40, |g: &mut Gen| {
        let layers = g.usize_in(1..=5);
        let steps = g.usize_in(15..=60);
        let g1 = g.f64_in(0.05, 1.0);
        let g2 = g1 + g.f64_in(0.0, 1.0);
        let traj: Vec<f64> = (0..steps).map(|s| 1.0 / (1.0 + s as f64)).collect();
        let count = |gamma: f64| {
            let mut p = Foresight::new(1, 2, gamma, 0.15).unwrap();
            drive_coarse(&mut p, layers, steps, |s, _| traj[s])
                .iter()
                .flatten()
                .filter(|&&x| x)
                .count()
        };
        let (c1, c2) = (count(g1), count(g2));
        prop_assert(
            c1 <= c2,
            format!("reuse count not monotone in gamma: g={g1:.3}→{c1}, g={g2:.3}→{c2}"),
        );
    });
}

#[test]
fn prop_static_reuse_pattern_exact() {
    proptest_cases(50, |g: &mut Gen| {
        let layers = g.usize_in(1..=8);
        let steps = g.usize_in(4..=60);
        let r = g.usize_in(1..=6);
        let mut p = StaticReuse::new(r.saturating_sub(1), r).unwrap();
        let decisions = drive_coarse(&mut p, layers, steps, |_, _| 0.0);
        for (step, row) in decisions.iter().enumerate() {
            let expect = step % r != 0;
            prop_assert(
                row.iter().all(|&x| x == expect),
                format!("static r={r} wrong at step {step}"),
            );
        }
    });
}

#[test]
fn prop_pab_hierarchy_holds() {
    proptest_cases(40, |g: &mut Gen| {
        let layers = g.usize_in(2..=8);
        let steps = g.usize_in(20..=80);
        let alpha = g.usize_in(2..=3);
        let beta = alpha + g.usize_in(1..=3);
        let gamma_c = beta + g.usize_in(1..=3);
        let mut p = Pab::new(alpha, beta, gamma_c, 0.1, 0.6, vec![0], 2, steps).unwrap();
        p.begin_request(layers, steps);
        let mut counts = [0usize; 3]; // spatial-attn, temporal-attn, cross
        for step in 0..steps {
            for layer in 0..layers {
                for (i, (kind, sub)) in [
                    (BlockKind::Spatial, SubUnit::Attn),
                    (BlockKind::Temporal, SubUnit::Attn),
                    (BlockKind::Spatial, SubUnit::Cross),
                ]
                .iter()
                .enumerate()
                {
                    let site = Site { layer, kind: *kind, unit: Unit::Sub(*sub), branch: 0 };
                    if p.action(step, site).is_reuse() {
                        counts[i] += 1;
                    }
                }
            }
        }
        prop_assert(
            counts[2] >= counts[1] && counts[1] >= counts[0],
            format!("pyramid violated: spatial {} temporal {} cross {}", counts[0], counts[1], counts[2]),
        );
    });
}

#[test]
fn prop_samplers_stay_finite_and_ordered() {
    proptest_cases(60, |g: &mut Gen| {
        let steps = g.usize_in(2..=120);
        let sched = ScheduleConfig { train_timesteps: 1000, beta_start: 1e-4, beta_end: 2e-2 };
        for kind in [SamplerKind::Ddim, SamplerKind::Rflow] {
            let s = sampler::build(kind, &sched, steps);
            prop_assert(s.n_steps() == steps, "step count");
            for i in 1..steps {
                prop_assert(
                    s.t_value(i) < s.t_value(i - 1),
                    format!("{kind:?}: t_value not strictly decreasing at {i}"),
                );
            }
            let n = g.usize_in(4..=64);
            let mut x = g.vec_normal(n);
            let out = g.vec_normal(n);
            for i in 0..steps {
                s.step(&mut x, &out, i);
            }
            prop_assert(
                x.iter().all(|v| v.is_finite()),
                format!("{kind:?}: non-finite latent"),
            );
        }
    });
}

#[test]
fn prop_json_roundtrip() {
    fn random_json(g: &mut Gen, depth: usize) -> Json {
        let choice = if depth == 0 { g.usize_in(0..=3) } else { g.usize_in(0..=5) };
        match choice {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num((g.f64_in(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => {
                let n = g.usize_in(0..=8);
                Json::Str((0..n).map(|i| ((b'a' + (i as u8 % 26)) as char)).collect())
            }
            4 => {
                let n = g.usize_in(0..=4);
                Json::Arr((0..n).map(|_| random_json(g, depth - 1)).collect())
            }
            _ => {
                let n = g.usize_in(0..=4);
                Json::Obj(
                    (0..n)
                        .map(|i| (format!("k{i}"), random_json(g, depth - 1)))
                        .collect(),
                )
            }
        }
    }
    proptest_cases(200, |g: &mut Gen| {
        let v = random_json(g, 3);
        let text = v.to_string();
        let back = json::parse(&text).expect("roundtrip parse");
        prop_assert(back == v, format!("roundtrip mismatch for {text}"));
    });
}

#[test]
fn prop_prompt_embedding_shape_and_determinism() {
    proptest_cases(60, |g: &mut Gen| {
        let n_words = g.usize_in(0..=40);
        let words: Vec<String> = (0..n_words)
            .map(|_| {
                let len = g.usize_in(1..=8);
                (0..len)
                    .map(|_| (b'a' + (g.usize_in(0..=25) as u8)) as char)
                    .collect()
            })
            .collect();
        let prompt = words.join(" ");
        let d = *g.pick(&[16usize, 32, 64]);
        let s = *g.pick(&[4usize, 8, 16]);
        let e1 = workload::embed_prompt(&prompt, d, s);
        let e2 = workload::embed_prompt(&prompt, d, s);
        prop_assert(e1.dims == vec![s, d], "dims");
        prop_assert(e1.data == e2.data, "determinism");
        prop_assert(e1.data.iter().all(|v| v.is_finite()), "finite");
        let c = workload::motion_complexity(&prompt);
        prop_assert((0.0..=1.0).contains(&c), format!("complexity {c}"));
    });
}

#[test]
fn prop_decisions_invariant_under_branch_interleaving() {
    // The engine runs the two CFG branches on concurrent threads, so the
    // per-site action/observe calls of one step can interleave across
    // branches in any order. Policy state is keyed per (layer, kind,
    // branch); this property drives a policy once branch-sequentially and
    // once branch-interleaved per site and asserts identical decisions —
    // the determinism contract the parallel hot path relies on.
    proptest_cases(40, |g: &mut Gen| {
        let layers = g.usize_in(1..=6);
        let steps = g.usize_in(10..=50);
        let spec = *g.pick(&["static", "foresight", "delta-dit"]);
        let info = fake_model(layers);
        let mse_for = |step: usize, layer: usize, branch: usize| {
            1.0 / (1.0 + step as f64 + layer as f64 * 0.3 + branch as f64 * 0.7)
        };

        let drive = |interleave: bool| -> Vec<bool> {
            let mut p = build_policy(spec, &info, steps).unwrap();
            p.begin_request(layers, steps);
            let mut out = Vec::new();
            for step in 0..steps {
                let do_site = |p: &mut dyn ReusePolicy,
                               out: &mut Vec<bool>,
                               branch: usize,
                               layer: usize,
                               kind: BlockKind| {
                    let site = coarse_site(layer, kind, branch);
                    let a = p.action(step, site);
                    if branch == 0 {
                        out.push(a.is_reuse());
                    }
                    if let Action::Compute { measure: true, .. } = a {
                        p.observe_mse(step, site, mse_for(step, layer, branch));
                    }
                };
                if interleave {
                    // per-site alternation with branch 1 leading — the
                    // finest-grained reordering two branch threads sharing
                    // the policy mutex can produce within a step
                    for layer in 0..layers {
                        for kind in BlockKind::ALL {
                            do_site(p.as_mut(), &mut out, 1, layer, kind);
                            do_site(p.as_mut(), &mut out, 0, layer, kind);
                        }
                    }
                } else {
                    for branch in [0usize, 1] {
                        for layer in 0..layers {
                            for kind in BlockKind::ALL {
                                do_site(p.as_mut(), &mut out, branch, layer, kind);
                            }
                        }
                    }
                }
            }
            out
        };

        prop_assert(
            drive(false) == drive(true),
            format!("{spec}: decisions depend on CFG-branch interleaving"),
        );
    });
}

#[test]
fn prop_autotune_grid_specs_round_trip_to_identical_policies() {
    // Every configuration the autotuner can emit must parse back via
    // build_policy to a policy *identical* to the directly-constructed
    // one: same display name, same decisions over a synthetic trajectory.
    // (All autotune knobs are coarse-granularity policies.)
    proptest_cases(80, |g: &mut Gen| {
        let knobs = match g.usize_in(0..=2) {
            0 => Knobs::NoReuse,
            1 => Knobs::Static { n: g.usize_in(1..=4), r: g.usize_in(1..=6) },
            _ => {
                let n = g.usize_in(1..=4);
                // round to grid-like precision so spec strings stay short;
                // Rust float Display round-trips exactly either way
                let gamma = (g.f64_in(0.05, 2.0) * 100.0).round() / 100.0;
                let warmup = (g.f64_in(0.01, 0.45) * 100.0).round() / 100.0;
                Knobs::Foresight { n, r: n + 1, gamma, warmup }
            }
        };
        let spec = knobs.spec();
        let layers = g.usize_in(1..=6);
        let steps = g.usize_in(8..=50);
        let info = fake_model(layers);

        let mut direct: Box<dyn ReusePolicy> = match &knobs {
            Knobs::NoReuse => Box::new(NoReuse::new()),
            Knobs::Static { n, r } => Box::new(StaticReuse::new(*n, *r).unwrap()),
            Knobs::Foresight { n, r, gamma, warmup } => {
                Box::new(Foresight::new(*n, *r, *gamma, *warmup).unwrap())
            }
        };
        let mut parsed = build_policy(&spec, &info, steps)
            .unwrap_or_else(|e| panic!("emitted spec '{spec}' failed to parse: {e}"));
        prop_assert(
            parsed.name() == direct.name(),
            format!("'{spec}': parsed name {} != direct {}", parsed.name(), direct.name()),
        );
        let mse = |s: usize, l: usize| 1.0 / (1.0 + s as f64 + 0.3 * l as f64);
        let d_parsed = drive_coarse(parsed.as_mut(), layers, steps, mse);
        let d_direct = drive_coarse(direct.as_mut(), layers, steps, mse);
        prop_assert(
            d_parsed == d_direct,
            format!("'{spec}': parsed and direct policies diverged"),
        );
    });
}

#[test]
fn default_grid_candidates_all_round_trip() {
    // The deterministic counterpart over the exact default grids.
    let info = fake_model(4);
    for grid in [GridSpec::paper_default(), GridSpec::tiny()] {
        for knobs in grid.candidates() {
            let spec = knobs.spec();
            let p1 = build_policy(&spec, &info, 30)
                .unwrap_or_else(|e| panic!("{spec}: {e}"));
            let p2 = build_policy(&spec, &info, 30).unwrap();
            assert_eq!(p1.name(), p2.name(), "{spec}");
        }
    }
}

#[test]
fn prop_foresight_lambda_matches_eq5_weighting() {
    // With constant warmup MSE m, Eq. 5 gives λ = m * (1 + 0.1 + 0.01).
    proptest_cases(40, |g: &mut Gen| {
        let m = g.f64_in(0.01, 5.0);
        let steps = g.usize_in(20..=60);
        let mut p = Foresight::new(1, 2, 0.5, 0.15).unwrap();
        p.begin_request(1, steps);
        let w = p.warmup_steps();
        for step in 1..w {
            p.observe_mse(step, coarse_site(0, BlockKind::Spatial, 0), m);
        }
        let lam = p.thresholds().unwrap()[&(0, BlockKind::Spatial, 0)];
        // Eq. 5 weights the last three warmup MSEs 10^-2, 10^-1, 10^0; MSEs
        // only exist from step 1, so a minimal W=3 warmup has two terms.
        let expect: f64 = (1..w)
            .filter(|s| s + 3 >= w)
            .map(|s| m * 10f64.powi(-((w - 1 - s) as i32)))
            .sum();
        prop_assert(
            (lam - expect).abs() < 1e-9 * (1.0 + expect),
            format!("λ={lam} expected {expect}"),
        );
    });
}
