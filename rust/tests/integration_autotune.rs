//! Autotune integration: profile a tiny grid on the real engine, select
//! within the quality budget, persist the store to disk, reload it, and
//! check the serving-side lookup contract. SKIPs without AOT artifacts.

use std::sync::Arc;

use foresight::autotune::{
    pareto_frontier, profile_engine, GridSpec, ProfileOptions, ProfileStore, DEFAULT_KNOBS,
};
use foresight::config::Manifest;
use foresight::engine::Engine;
use foresight::model::LoadedModel;
use foresight::runtime::Runtime;

const STEPS: usize = 6;

fn load_engine() -> Option<Engine> {
    let root = Manifest::default_root();
    if !root.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts — run `make artifacts`");
        return None;
    }
    let manifest = Manifest::load(&root).unwrap();
    let rt = Arc::new(Runtime::cpu().unwrap());
    let lm = Arc::new(LoadedModel::load(rt, &manifest, "opensora-sim", "240p-2s").unwrap());
    Some(Engine::new(lm, manifest.schedule))
}

#[test]
fn profile_select_persist_reload() {
    let Some(engine) = load_engine() else { return };
    let opts = ProfileOptions {
        steps: Some(STEPS),
        prompts: 2,
        min_psnr: 25.0,
        grid: GridSpec::tiny(),
    };
    let outcome = profile_engine(&engine, &opts).unwrap();
    let profile = &outcome.profile;

    // The sweep holds the baseline and the serving default; the stored
    // frontier is exactly the Pareto frontier of the sweep.
    assert!(outcome.points.iter().any(|p| p.spec == "none"));
    let default_spec = DEFAULT_KNOBS.spec();
    let def = outcome
        .points
        .iter()
        .find(|p| p.spec == default_spec)
        .expect("sweep includes the serving default");
    assert_eq!(pareto_frontier(&outcome.points), profile.frontier);

    // Budgeted selection Pareto-dominates or matches the fixed default.
    let chosen = outcome
        .points
        .iter()
        .find(|p| p.spec == profile.spec)
        .expect("chosen spec is a sweep point");
    if def.psnr >= opts.min_psnr {
        assert!(chosen.psnr >= opts.min_psnr, "{:.2}", chosen.psnr);
        assert!(
            chosen.wall_s <= def.wall_s,
            "tuned {:.3}s slower than default {:.3}s",
            chosen.wall_s,
            def.wall_s
        );
    } else {
        assert!(chosen.psnr >= def.psnr, "{:.2} vs {:.2}", chosen.psnr, def.psnr);
    }

    // Key matches the engine's identity.
    let info = &engine.model().info;
    let bucket = &engine.model().bucket.name;
    assert_eq!(profile.key.model, info.name);
    assert_eq!(&profile.key.bucket, bucket);
    assert_eq!(profile.key.sampler, info.sampler.name());
    assert_eq!(profile.key.steps, STEPS);

    // Filesystem round trip: save → load → identical exact lookup.
    let path = std::env::temp_dir()
        .join(format!("foresight-autotune-test-{}.json", std::process::id()));
    let mut store = ProfileStore::new();
    store.insert(outcome.profile.clone());
    store.save(&path).unwrap();
    let loaded = ProfileStore::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);

    assert_eq!(loaded.version(), store.version());
    let from_disk = loaded
        .lookup(&info.name, bucket, info.sampler.name(), STEPS)
        .expect("saved profile must resolve");
    let in_memory = store
        .lookup(&info.name, bucket, info.sampler.name(), STEPS)
        .unwrap();
    assert_eq!(from_disk.kind(), "exact");
    assert_eq!(from_disk.profile(), in_memory.profile());

    // The nearest-steps fallback reaches the same profile from a
    // neighboring step count (the serving path for unprofiled steps).
    let near = loaded
        .lookup(&info.name, "some-other-bucket", info.sampler.name(), STEPS + 2)
        .expect("nearest fallback must resolve");
    assert_eq!(near.kind(), "nearest");
    assert_eq!(near.profile().spec, profile.spec);
}
