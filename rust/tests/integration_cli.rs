//! CLI front-end: option-parsing contracts that must fail fast, before
//! any artifact or device work — these tests need no artifacts and run
//! everywhere.

use std::process::Command;

#[test]
fn serve_rejects_removed_gather_ms_alias() {
    // `--gather-ms` was a deprecated alias of `--admit-ms` from the
    // pre-continuous-batching server; it is gone, so a stale deploy
    // script fails loudly at parse time instead of silently serving with
    // the default admission window.
    let out = Command::new(env!("CARGO_BIN_EXE_foresight"))
        .args(["serve", "--gather-ms", "5"])
        .output()
        .expect("spawn foresight");
    assert!(
        !out.status.success(),
        "serve --gather-ms must be rejected, got {:?}",
        out.status
    );
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("unknown option --gather-ms"),
        "stderr: {stderr}"
    );
    // the parse error carries the help text, so the replacement knob and
    // the overload-control options are advertised in the same breath
    assert!(stderr.contains("--admit-ms"), "stderr: {stderr}");
    assert!(stderr.contains("--max-queue"), "stderr: {stderr}");
    assert!(stderr.contains("--degrade"), "stderr: {stderr}");
}

#[test]
fn unknown_command_fails_with_usage() {
    let out = Command::new(env!("CARGO_BIN_EXE_foresight"))
        .arg("warp")
        .output()
        .expect("spawn foresight");
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown command 'warp'"), "stderr: {stderr}");
    assert!(stderr.contains("serve"), "stderr: {stderr}");
}
