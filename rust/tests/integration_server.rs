//! Server integration: protocol round-trips, concurrent clients, error
//! handling, queue/latency telemetry.

use std::sync::Arc;

use foresight::autotune::{ProfileKey, ProfilePoint, ProfileStore, TunedProfile};
use foresight::config::Manifest;
use foresight::runtime::DevicePool;
use foresight::server::{is_overloaded, Client, EngineRegistry, Server, ServerConfig};
use foresight::util::json::Json;

/// `FORESIGHT_TEST_DEVICES=N` re-runs the whole suite against a sharded
/// N-replica pool (CI runs it once at N=2); the default stays the classic
/// single-runtime topology.
fn test_devices() -> usize {
    std::env::var("FORESIGHT_TEST_DEVICES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
        .max(1)
}

/// Start a server on `devices` replicas with the given (model, bucket)
/// pairs loaded on every replica.
fn start_server_pairs(
    mut cfg: ServerConfig,
    devices: usize,
    pairs: &[(&str, &str)],
) -> Option<Server> {
    let root = Manifest::default_root();
    if !root.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts — run `make artifacts`");
        return None;
    }
    let manifest = Manifest::load(&root).unwrap();
    let pool = Arc::new(DevicePool::cpu(devices).unwrap());
    let pairs: Vec<(String, String)> = pairs
        .iter()
        .map(|(m, b)| (m.to_string(), b.to_string()))
        .collect();
    let registry = Arc::new(EngineRegistry::load_pool(pool, &manifest, &pairs).unwrap());
    cfg.devices = devices;
    Some(Server::start(registry, cfg).unwrap())
}

fn start_server_with(cfg: ServerConfig) -> Option<Server> {
    start_server_pairs(cfg, test_devices(), &[("opensora-sim", "240p-2s")])
}

fn start_server(workers: usize) -> Option<Server> {
    start_server_with(ServerConfig {
        addr: "127.0.0.1:0".into(),
        workers,
        ..ServerConfig::default()
    })
}

fn gen_req_bucket(bucket: &str, policy: &str, prompt: &str, seed: u64, steps: usize) -> Json {
    Json::obj(vec![
        ("op", Json::str("generate")),
        ("model", Json::str("opensora-sim")),
        ("bucket", Json::str(bucket)),
        ("policy", Json::str(policy)),
        ("prompt", Json::str(prompt)),
        ("seed", Json::num(seed as f64)),
        ("steps", Json::num(steps as f64)),
    ])
}

fn gen_req(policy: &str, prompt: &str, seed: u64, steps: usize) -> Json {
    gen_req_bucket("240p-2s", policy, prompt, seed, steps)
}

#[test]
fn ping_generate_stats_roundtrip() {
    let Some(server) = start_server(1) else { return };
    let mut c = Client::connect(&server.addr()).unwrap();
    assert!(c.ping().unwrap());

    let resp = c.call(&gen_req("foresight", "a calm lake", 1, 12)).unwrap();
    assert_eq!(resp.get("status").unwrap().as_str().unwrap(), "ok", "{resp}");
    assert_eq!(resp.get("steps").unwrap().as_usize().unwrap(), 12);
    assert!(resp.get("wall_s").unwrap().as_f64().unwrap() > 0.0);
    assert!(resp.get("reused_units").unwrap().as_f64().unwrap() > 0.0);
    // wire-visible batching + equivalence fields ride along
    assert!(resp.get("batch_size").unwrap().as_usize().unwrap() >= 1);
    assert!(resp.get("latent_l2").unwrap().as_f64().unwrap() > 0.0);

    let stats = c.call(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
    assert_eq!(stats.get("requests").unwrap().as_usize().unwrap(), 1);
    assert_eq!(stats.get("errors").unwrap().as_usize().unwrap(), 0);
    assert!(stats.get("latency_mean_s").unwrap().as_f64().unwrap() > 0.0);

    server.shutdown();
}

#[test]
fn concurrent_clients_all_served() {
    let Some(server) = start_server(2) else { return };
    let addr = server.addr();
    let mut handles = Vec::new();
    for cid in 0..3u64 {
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            let resp = c
                .call(&gen_req("static", &format!("prompt {cid}"), cid, 8))
                .unwrap();
            assert_eq!(resp.get("status").unwrap().as_str().unwrap(), "ok", "{resp}");
            resp.get("wall_s").unwrap().as_f64().unwrap()
        }));
    }
    for h in handles {
        assert!(h.join().unwrap() > 0.0);
    }
    let mut c = Client::connect(&addr).unwrap();
    let stats = c.call(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
    assert_eq!(stats.get("requests").unwrap().as_usize().unwrap(), 3);
    server.shutdown();
}

#[test]
fn errors_are_reported_not_fatal() {
    let Some(server) = start_server(1) else { return };
    let mut c = Client::connect(&server.addr()).unwrap();

    // unknown op
    let r = c.call(&Json::obj(vec![("op", Json::str("warp"))])).unwrap();
    assert_eq!(r.get("status").unwrap().as_str().unwrap(), "error");

    // unknown model
    let mut bad = gen_req("foresight", "x", 0, 4);
    if let Json::Obj(ref mut o) = bad {
        o.insert("model".into(), Json::str("nope"));
    }
    let r3 = c.call(&bad).unwrap();
    assert_eq!(r3.get("status").unwrap().as_str().unwrap(), "error");

    // unknown policy
    let r4 = c.call(&gen_req("warp-drive", "x", 0, 4)).unwrap();
    assert_eq!(r4.get("status").unwrap().as_str().unwrap(), "error");

    // server still alive and serving
    let ok = c.call(&gen_req("none", "recovery check", 0, 4)).unwrap();
    assert_eq!(ok.get("status").unwrap().as_str().unwrap(), "ok");
    server.shutdown();
}

#[test]
fn invalid_generate_requests_are_rejected_without_killing_workers() {
    // `steps: 0` used to trip the sampler constructor's assert, panic the
    // worker, and turn every later request on that worker into "worker
    // dropped". With a single worker, a successful request after each
    // rejection proves the worker survived validation.
    let Some(server) = start_server(1) else { return };
    let mut c = Client::connect(&server.addr()).unwrap();

    let r = c.call(&gen_req("none", "x", 0, 0)).unwrap();
    assert_eq!(r.get("status").unwrap().as_str().unwrap(), "error", "{r}");
    assert!(
        r.get("error").unwrap().as_str().unwrap().contains("steps"),
        "{r}"
    );

    // non-numeric cfg_scale is rejected, not panicked on
    let mut bad = gen_req("none", "x", 0, 4);
    if let Json::Obj(ref mut o) = bad {
        o.insert("cfg_scale".into(), Json::str("very"));
    }
    let r2 = c.call(&bad).unwrap();
    assert_eq!(r2.get("status").unwrap().as_str().unwrap(), "error", "{r2}");

    // non-numeric seed likewise
    let mut bad_seed = gen_req("none", "x", 0, 4);
    if let Json::Obj(ref mut o) = bad_seed {
        o.insert("seed".into(), Json::str("tomorrow"));
    }
    let r3 = c.call(&bad_seed).unwrap();
    assert_eq!(r3.get("status").unwrap().as_str().unwrap(), "error", "{r3}");

    // fractional seed is rejected like fractional steps — `1.5 as u64`
    // used to truncate silently to seed 1 and serve the wrong video
    let mut frac_seed = gen_req("none", "x", 0, 4);
    if let Json::Obj(ref mut o) = frac_seed {
        o.insert("seed".into(), Json::num(1.5));
    }
    let r4 = c.call(&frac_seed).unwrap();
    assert_eq!(r4.get("status").unwrap().as_str().unwrap(), "error", "{r4}");
    assert!(
        r4.get("error").unwrap().as_str().unwrap().contains("seed"),
        "{r4}"
    );

    // the same (only) worker still serves valid requests afterwards
    let ok = c.call(&gen_req("none", "recovery", 1, 4)).unwrap();
    assert_eq!(ok.get("status").unwrap().as_str().unwrap(), "ok", "{ok}");

    // errors were counted, not fatal
    let stats = c.call(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
    assert_eq!(stats.get("errors").unwrap().as_usize().unwrap(), 4);
    server.shutdown();
}

#[test]
fn cfg_scale_is_plumbed_and_transfer_counters_echoed() {
    let Some(server) = start_server(1) else { return };
    let mut c = Client::connect(&server.addr()).unwrap();

    let mut req = gen_req("none", "counter prompt", 3, 6);
    if let Json::Obj(ref mut o) = req {
        o.insert("cfg_scale".into(), Json::num(4.5));
    }
    let r = c.call(&req).unwrap();
    assert_eq!(r.get("status").unwrap().as_str().unwrap(), "ok", "{r}");
    // the transfer meters ride along in the response
    for k in ["h2d_bytes", "h2d_calls", "d2h_bytes", "d2h_calls"] {
        assert!(
            r.get(k).unwrap().as_f64().unwrap() > 0.0,
            "{k} missing or zero: {r}"
        );
    }
    // transfer volume is cfg-scale-independent: the same request with the
    // preset default moves exactly the same bytes (the scale is a rank-0
    // runtime argument, not a recompile)
    let r2 = c.call(&gen_req("none", "counter prompt", 3, 6)).unwrap();
    assert_eq!(r2.get("status").unwrap().as_str().unwrap(), "ok", "{r2}");
    for k in ["h2d_bytes", "d2h_bytes"] {
        assert_eq!(
            r.get(k).unwrap().as_f64().unwrap(),
            r2.get(k).unwrap().as_f64().unwrap(),
            "{k} must not depend on cfg_scale"
        );
    }
    server.shutdown();
}

#[test]
fn shutdown_is_prompt_with_idle_workers() {
    // Workers park on the queue condvar; shutdown must notify them rather
    // than relying on a poll interval, so joining an idle pool is fast.
    let Some(server) = start_server(4) else { return };
    let t0 = std::time::Instant::now();
    server.shutdown();
    let took = t0.elapsed();
    assert!(
        took < std::time::Duration::from_secs(1),
        "idle shutdown should be immediate, took {took:?}"
    );
}

#[test]
fn compatible_concurrent_clients_batch_and_match_sequential() {
    // K concurrent clients with the same (model, bucket, policy, steps)
    // but distinct prompts/seeds must coalesce into shared device passes
    // and receive exactly the results a sequential server would have
    // produced (latent checksum ≤1e-6, identical decision counters).
    let Some(server) = start_server_with(ServerConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        max_batch: 4,
        admit_window_ms: 500,
        ..ServerConfig::default()
    }) else {
        return;
    };
    let addr = server.addr();
    const K: u64 = 3;
    let req_for = |cid: u64| gen_req("foresight", &format!("batched prompt {cid}"), cid, 8);

    // Sequential reference: one client, one request at a time.
    let mut reference = Vec::new();
    {
        let mut c = Client::connect(&addr).unwrap();
        for cid in 0..K {
            let r = c.call(&req_for(cid)).unwrap();
            assert_eq!(r.get("status").unwrap().as_str().unwrap(), "ok", "{r}");
            reference.push((
                r.get("latent_l2").unwrap().as_f64().unwrap(),
                r.get("computed_units").unwrap().as_f64().unwrap(),
                r.get("reused_units").unwrap().as_f64().unwrap(),
            ));
        }
    }

    // Concurrent phase: pre-connect every client, then fire simultaneously
    // so all K jobs are queued well inside the gather window.
    let mut handles = Vec::new();
    for cid in 0..K {
        let req = req_for(cid);
        let mut c = Client::connect(&addr).unwrap();
        assert!(c.ping().unwrap());
        handles.push(std::thread::spawn(move || {
            let r = c.call(&req).unwrap();
            (cid, r)
        }));
    }
    let mut max_batch_seen = 0usize;
    for h in handles {
        let (cid, r) = h.join().unwrap();
        assert_eq!(r.get("status").unwrap().as_str().unwrap(), "ok", "{r}");
        let (l2, computed, reused) = reference[cid as usize];
        let got_l2 = r.get("latent_l2").unwrap().as_f64().unwrap();
        assert!(
            (got_l2 - l2).abs() <= 1e-6 * (1.0 + l2.abs()),
            "client {cid}: batched latent_l2 {got_l2} vs sequential {l2}"
        );
        assert_eq!(r.get("computed_units").unwrap().as_f64().unwrap(), computed, "{cid}");
        assert_eq!(r.get("reused_units").unwrap().as_f64().unwrap(), reused, "{cid}");
        max_batch_seen = max_batch_seen.max(r.get("batch_size").unwrap().as_usize().unwrap());
    }
    // With one worker and a wide gather window, the simultaneous clients
    // must actually have shared an engine pass.
    assert!(
        max_batch_seen >= 2,
        "expected at least one multi-request pass, max batch_size {max_batch_seen}"
    );
    server.shutdown();
}

#[test]
fn mixed_steps_cfg_policy_requests_share_passes_and_match_solo() {
    // The continuous scheduler's headline: requests that differ in steps,
    // cfg_scale AND policy share device passes (each session carries its
    // own schedule cursor and CFG scalar), each finishing on its own
    // schedule with exactly its standalone result.
    let Some(server) = start_server_with(ServerConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        max_batch: 4,
        admit_window_ms: 500,
        ..ServerConfig::default()
    }) else {
        return;
    };
    let addr = server.addr();
    let mut cases: Vec<Json> = vec![
        gen_req("none", "mixed a", 1, 6),
        gen_req("none", "mixed b", 2, 9),   // different steps
        gen_req("static", "mixed c", 3, 6), // different policy
    ];
    if let Json::Obj(ref mut o) = cases[2] {
        o.insert("cfg_scale".into(), Json::num(3.5)); // different cfg too
    }

    // Solo references first (fresh server state not needed: sessions are
    // per-request, so solo vs cohort must be identical).
    let mut reference = Vec::new();
    {
        let mut c = Client::connect(&addr).unwrap();
        for (i, req) in cases.iter().enumerate() {
            let r = c.call(req).unwrap();
            assert_eq!(r.get("status").unwrap().as_str().unwrap(), "ok", "solo {i}: {r}");
            reference.push((
                r.get("latent_l2").unwrap().as_f64().unwrap(),
                r.get("steps").unwrap().as_usize().unwrap(),
                r.get("computed_units").unwrap().as_f64().unwrap(),
            ));
        }
    }

    let mut handles = Vec::new();
    for (i, req) in cases.into_iter().enumerate() {
        let mut c = Client::connect(&addr).unwrap();
        assert!(c.ping().unwrap());
        handles.push(std::thread::spawn(move || (i, c.call(&req).unwrap())));
    }
    let mut max_batch_seen = 0usize;
    for h in handles {
        let (i, r) = h.join().unwrap();
        assert_eq!(r.get("status").unwrap().as_str().unwrap(), "ok", "case {i}: {r}");
        let (l2, steps, computed) = reference[i];
        assert_eq!(r.get("steps").unwrap().as_usize().unwrap(), steps, "case {i}");
        assert_eq!(r.get("computed_units").unwrap().as_f64().unwrap(), computed, "case {i}");
        let got = r.get("latent_l2").unwrap().as_f64().unwrap();
        assert!(
            (got - l2).abs() <= 1e-6 * (1.0 + l2.abs()),
            "case {i}: cohort latent_l2 {got} vs solo {l2}"
        );
        max_batch_seen = max_batch_seen.max(r.get("batch_size").unwrap().as_usize().unwrap());
    }
    assert!(
        max_batch_seen >= 2,
        "mixed-parameter requests must share a pass under the continuous \
         scheduler, max batch_size {max_batch_seen}"
    );
    server.shutdown();
}

#[test]
fn request_admitted_midflight_joins_and_both_finish() {
    // A request that arrives while a cohort is already stepping must join
    // at a step boundary (not wait the in-flight request out), share the
    // pass, retire on its own schedule, and return its standalone result.
    let Some(server) = start_server_with(ServerConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        max_batch: 4,
        admit_window_ms: 0,
        ..ServerConfig::default()
    }) else {
        return;
    };
    let addr = server.addr();
    let joiner = gen_req("foresight", "midflight joiner", 5, 6);

    // Solo reference for the joiner.
    let ref_l2 = {
        let mut c = Client::connect(&addr).unwrap();
        let r = c.call(&joiner).unwrap();
        assert_eq!(r.get("status").unwrap().as_str().unwrap(), "ok", "{r}");
        r.get("latent_l2").unwrap().as_f64().unwrap()
    };

    // Occupy the only worker with a long schedule.
    let long_req = gen_req("foresight", "long hauler", 6, 30);
    let mut c_long = Client::connect(&addr).unwrap();
    let h_long = std::thread::spawn(move || c_long.call(&long_req).unwrap());

    // Wait until the long request is actually in flight, then join.
    let mut c = Client::connect(&addr).unwrap();
    let t0 = std::time::Instant::now();
    loop {
        let s = c.call(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
        if s.get("lanes_active").unwrap().as_usize().unwrap() >= 1 {
            break;
        }
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(10),
            "long request never started: {s}"
        );
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    let r = c.call(&joiner).unwrap();
    assert_eq!(r.get("status").unwrap().as_str().unwrap(), "ok", "{r}");
    assert!(
        r.get("batch_size").unwrap().as_usize().unwrap() >= 2,
        "joiner should have shared an in-flight pass: {r}"
    );
    assert_eq!(r.get("steps").unwrap().as_usize().unwrap(), 6, "{r}");
    let got = r.get("latent_l2").unwrap().as_f64().unwrap();
    assert!(
        (got - ref_l2).abs() <= 1e-6 * (1.0 + ref_l2.abs()),
        "joiner diverged from its solo run: {got} vs {ref_l2}"
    );

    let r_long = h_long.join().unwrap();
    assert_eq!(r_long.get("status").unwrap().as_str().unwrap(), "ok", "{r_long}");
    assert_eq!(r_long.get("steps").unwrap().as_usize().unwrap(), 30, "{r_long}");
    assert!(
        r_long.get("batch_size").unwrap().as_usize().unwrap() >= 2,
        "the in-flight request should have seen the joiner: {r_long}"
    );

    let stats = c.call(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
    assert!(
        stats.get("joins").unwrap().as_usize().unwrap() >= 1,
        "mid-flight join must be counted: {stats}"
    );
    assert!(stats.get("retires").unwrap().as_usize().unwrap() >= 3, "{stats}");
    assert!(stats.get("occupancy_max").unwrap().as_f64().unwrap() >= 2.0, "{stats}");
    server.shutdown();
}

#[test]
fn stats_reservoir_caps_samples_and_reports_percentiles() {
    // The latency/queue telemetry is a bounded reservoir: exact until the
    // cap, sampled (but still counting everything seen) beyond it.
    let Some(server) = start_server_with(ServerConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        max_batch: 1, // isolate telemetry from batching
        admit_window_ms: 0,
        telemetry_reservoir: 4,
        profiles: None,
    }) else {
        return;
    };
    let mut c = Client::connect(&server.addr()).unwrap();
    for seed in 0..6u64 {
        let r = c.call(&gen_req("none", "stats probe", seed, 2)).unwrap();
        assert_eq!(r.get("status").unwrap().as_str().unwrap(), "ok", "{r}");
        assert_eq!(r.get("batch_size").unwrap().as_usize().unwrap(), 1);
    }
    let stats = c.call(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
    assert_eq!(stats.get("requests").unwrap().as_usize().unwrap(), 6);
    assert_eq!(
        stats.get("latency_samples").unwrap().as_usize().unwrap(),
        4,
        "reservoir must cap at its configured size: {stats}"
    );
    assert_eq!(stats.get("latency_seen").unwrap().as_usize().unwrap(), 6);
    for k in ["latency_p50_s", "latency_p95_s", "latency_p99_s", "latency_mean_s"] {
        assert!(
            stats.get(k).unwrap().as_f64().unwrap() > 0.0,
            "{k} missing or zero: {stats}"
        );
    }
    // p99 dominates p50 over the same reservoir
    assert!(
        stats.get("latency_p99_s").unwrap().as_f64().unwrap()
            >= stats.get("latency_p50_s").unwrap().as_f64().unwrap()
    );
    // queue percentiles exist (near-zero on an idle single client is fine)
    assert!(stats.get("queue_p95_s").unwrap().as_f64().unwrap() >= 0.0);
    assert!(stats.get("accept_errors").unwrap().as_f64().unwrap() >= 0.0);
    server.shutdown();
}

/// A store with one tuned profile for opensora-sim/240p-2s at `steps`,
/// under both sampler names so the test doesn't hardcode the preset's
/// sampler family.
fn tuned_store(steps: usize, spec: &str) -> Arc<ProfileStore> {
    let mut store = ProfileStore::new();
    for sampler in ["rflow", "ddim"] {
        store.insert(TunedProfile {
            key: ProfileKey {
                model: "opensora-sim".into(),
                bucket: "240p-2s".into(),
                sampler: sampler.into(),
                steps,
            },
            spec: spec.into(),
            min_psnr: 25.0,
            profile_version: 1,
            frontier: vec![],
        });
    }
    Arc::new(store)
}

#[test]
fn policy_auto_without_profiles_falls_back_and_counts() {
    let Some(server) = start_server(1) else { return };
    let mut c = Client::connect(&server.addr()).unwrap();
    let r = c.call(&gen_req("auto", "auto fallback probe", 1, 6)).unwrap();
    assert_eq!(r.get("status").unwrap().as_str().unwrap(), "ok", "{r}");
    assert_eq!(r.get("policy_requested").unwrap().as_str().unwrap(), "auto");
    assert_eq!(r.get("resolved_policy").unwrap().as_str().unwrap(), "foresight");
    assert_eq!(r.get("policy_spec").unwrap().as_str().unwrap(), "foresight");
    assert!(r.get("profile_fallback").unwrap().as_bool().unwrap(), "{r}");
    assert_eq!(r.get("profile_match").unwrap().as_str().unwrap(), "default");
    assert_eq!(r.get("profile_version").unwrap().as_usize().unwrap(), 0);

    // explicit requests carry the concrete spec but no auto echo
    let r2 = c.call(&gen_req("static:n=1,r=2", "explicit", 2, 6)).unwrap();
    assert_eq!(r2.get("policy_spec").unwrap().as_str().unwrap(), "static:n=1,r=2");
    assert!(r2.get("policy_requested").is_none(), "{r2}");

    let stats = c.call(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
    assert_eq!(stats.get("auto_fallbacks").unwrap().as_usize().unwrap(), 1);
    assert_eq!(stats.get("auto_resolved").unwrap().as_usize().unwrap(), 0);
    assert_eq!(stats.get("profile_store_version").unwrap().as_usize().unwrap(), 0);
    assert_eq!(stats.get("profiles_loaded").unwrap().as_usize().unwrap(), 0);
    server.shutdown();
}

#[test]
fn policy_auto_resolves_tuned_spec_and_batches_with_explicit() {
    const STEPS: usize = 8;
    const TUNED: &str = "static:n=1,r=2";
    let Some(server) = start_server_with(ServerConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        max_batch: 4,
        admit_window_ms: 500,
        profiles: Some(tuned_store(STEPS, TUNED)),
        ..ServerConfig::default()
    }) else {
        return;
    };
    let addr = server.addr();

    // Two `auto` requests and one explicit request with the tuned spec,
    // fired simultaneously at a single worker: `auto` resolves *before*
    // the batch key is formed, so all three carry identical raw policy
    // fields and must share an engine pass.
    let mut handles = Vec::new();
    for (cid, policy) in [(0u64, "auto"), (1, "auto"), (2, TUNED)] {
        let req = gen_req(policy, &format!("auto batch {cid}"), cid, STEPS);
        let mut c = Client::connect(&addr).unwrap();
        assert!(c.ping().unwrap());
        handles.push(std::thread::spawn(move || (cid, c.call(&req).unwrap())));
    }
    let mut max_batch_seen = 0usize;
    for h in handles {
        let (cid, r) = h.join().unwrap();
        assert_eq!(r.get("status").unwrap().as_str().unwrap(), "ok", "{cid}: {r}");
        assert_eq!(r.get("policy_spec").unwrap().as_str().unwrap(), TUNED, "{cid}: {r}");
        if cid < 2 {
            assert_eq!(r.get("policy_requested").unwrap().as_str().unwrap(), "auto");
            assert_eq!(r.get("resolved_policy").unwrap().as_str().unwrap(), TUNED);
            assert_eq!(r.get("profile_match").unwrap().as_str().unwrap(), "exact");
            assert_eq!(r.get("profile_version").unwrap().as_usize().unwrap(), 1);
            assert!(!r.get("profile_fallback").unwrap().as_bool().unwrap(), "{r}");
        } else {
            assert!(r.get("policy_requested").is_none(), "{r}");
        }
        max_batch_seen = max_batch_seen.max(r.get("batch_size").unwrap().as_usize().unwrap());
    }
    assert!(
        max_batch_seen >= 2,
        "auto-resolved and explicit requests with the same concrete spec \
         must share an engine pass, max batch_size {max_batch_seen}"
    );

    // No exact profile at steps=6: the nearest same-(model, sampler)
    // profile (steps=8) is substituted, counted as a resolution.
    let mut c = Client::connect(&addr).unwrap();
    let r = c.call(&gen_req("auto", "nearest probe", 9, 6)).unwrap();
    assert_eq!(r.get("status").unwrap().as_str().unwrap(), "ok", "{r}");
    assert_eq!(r.get("profile_match").unwrap().as_str().unwrap(), "nearest");
    assert_eq!(r.get("resolved_policy").unwrap().as_str().unwrap(), TUNED);

    let stats = c.call(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
    assert_eq!(stats.get("auto_resolved").unwrap().as_usize().unwrap(), 3);
    assert_eq!(stats.get("auto_fallbacks").unwrap().as_usize().unwrap(), 0);
    assert!(stats.get("profile_store_version").unwrap().as_usize().unwrap() >= 1);
    assert_eq!(stats.get("profiles_loaded").unwrap().as_usize().unwrap(), 2);
    server.shutdown();
}

#[test]
fn policy_auto_with_unparseable_stored_spec_falls_back() {
    // A hand-edited (or newer-format) store whose tuned spec this build
    // cannot parse must not turn auto traffic into dispatch errors counted
    // as successful resolutions — it serves the default, counted as a
    // fallback.
    const STEPS: usize = 6;
    let Some(server) = start_server_with(ServerConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        profiles: Some(tuned_store(STEPS, "warp-drive:q=1")),
        ..ServerConfig::default()
    }) else {
        return;
    };
    let mut c = Client::connect(&server.addr()).unwrap();
    let r = c.call(&gen_req("auto", "corrupt store probe", 1, STEPS)).unwrap();
    assert_eq!(r.get("status").unwrap().as_str().unwrap(), "ok", "{r}");
    assert_eq!(r.get("resolved_policy").unwrap().as_str().unwrap(), "foresight");
    assert!(r.get("profile_fallback").unwrap().as_bool().unwrap(), "{r}");
    assert_eq!(r.get("profile_match").unwrap().as_str().unwrap(), "default");
    let stats = c.call(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
    assert_eq!(stats.get("auto_fallbacks").unwrap().as_usize().unwrap(), 1);
    assert_eq!(stats.get("auto_resolved").unwrap().as_usize().unwrap(), 0);
    server.shutdown();
}

#[test]
fn wire_reachable_policy_params_cannot_panic_workers() {
    // Each of these used to trip an assert! in a policy constructor at
    // dispatch time, killing the worker thread. With a single worker, a
    // successful request after the batch of rejections proves the worker
    // survived them all.
    let Some(server) = start_server(1) else { return };
    let mut c = Client::connect(&server.addr()).unwrap();
    for bad in [
        "foresight:gamma=-1",
        "foresight:gamma=0",
        "foresight:warmup=1.5",
        "foresight:r=0",
        "static:r=0",
        "delta-dit:k=0",
        "tgate:m=0",
        "pab:lo=0.9,hi=0.1",
        "foresight:g=0.5", // unknown key: rejected, not silently ignored
        "foresight:gamma=abc",
    ] {
        let r = c.call(&gen_req(bad, "bad params", 0, 4)).unwrap();
        assert_eq!(r.get("status").unwrap().as_str().unwrap(), "error", "{bad}: {r}");
    }
    let ok = c.call(&gen_req("foresight:gamma=0.5", "recovery", 1, 4)).unwrap();
    assert_eq!(ok.get("status").unwrap().as_str().unwrap(), "ok", "{ok}");
    server.shutdown();
}

#[test]
fn per_key_fifo_completion_order_with_interleaved_cohorts() {
    // Regression for the FIFO-prefix fence under the per-device queue
    // rework: two interleaved cohort keys (two shape buckets) queued
    // behind a long request on a single device must complete in per-key
    // FIFO order — the fence admits only the compatible queue *prefix*,
    // so A1 B1 A2 B2 may regroup across keys but never within one.
    // Pinned to one device: cross-device completion order is unordered by
    // design (that's what routing is for).
    let Some(server) = start_server_pairs(
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: 1,
            max_batch: 2,
            admit_window_ms: 0,
            ..ServerConfig::default()
        },
        1,
        &[("opensora-sim", "240p-2s"), ("opensora-sim", "240p-4s")],
    ) else {
        return;
    };
    let addr = server.addr();

    // Occupy the only worker so the interleaved arrivals actually queue.
    let plug = gen_req("foresight", "queue plug", 1, 60);
    let mut c_plug = Client::connect(&addr).unwrap();
    let h_plug = std::thread::spawn(move || c_plug.call(&plug).unwrap());
    {
        let mut c = Client::connect(&addr).unwrap();
        let t0 = std::time::Instant::now();
        loop {
            let s = c.call(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
            if s.get("lanes_active").unwrap().as_usize().unwrap() >= 1 {
                break;
            }
            assert!(t0.elapsed() < std::time::Duration::from_secs(10), "plug never started");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
    }

    // Interleave the two keys in a known arrival order: A1 B1 A2 B2.
    // A1 shares the plug's key and may join its cohort mid-flight; the
    // fence then parks B1 A2 B2 in arrival order (different-key front)
    // until the plug drains. Either way the property under test is only
    // the per-key completion order.
    let cases = [
        ("240p-2s", "fifo a1"),
        ("240p-4s", "fifo b1"),
        ("240p-2s", "fifo a2"),
        ("240p-4s", "fifo b2"),
    ];
    let mut handles = Vec::new();
    for (i, (bucket, prompt)) in cases.into_iter().enumerate() {
        let req = gen_req_bucket(bucket, "none", prompt, i as u64, 4);
        let mut c = Client::connect(&addr).unwrap();
        assert!(c.ping().unwrap());
        handles.push(std::thread::spawn(move || {
            let r = c.call(&req).unwrap();
            (i, bucket, std::time::Instant::now(), r)
        }));
        // Generous stagger: each request is enqueued (the server reads and
        // queues it synchronously on its conn thread) well before the next
        // client fires, fixing the arrival order while the plug steps.
        std::thread::sleep(std::time::Duration::from_millis(150));
    }

    let mut done = Vec::new();
    for h in handles {
        let (i, bucket, t_done, r) = h.join().unwrap();
        assert_eq!(r.get("status").unwrap().as_str().unwrap(), "ok", "case {i}: {r}");
        done.push((i, bucket, t_done));
    }
    let plug_r = h_plug.join().unwrap();
    assert_eq!(plug_r.get("status").unwrap().as_str().unwrap(), "ok", "{plug_r}");

    for key in ["240p-2s", "240p-4s"] {
        let times: Vec<_> = {
            let mut of_key: Vec<_> = done.iter().filter(|(_, b, _)| *b == key).collect();
            of_key.sort_by_key(|(i, _, _)| *i);
            of_key.iter().map(|(_, _, t)| *t).collect()
        };
        assert_eq!(times.len(), 2);
        assert!(
            times[0] <= times[1],
            "per-key FIFO violated for {key}: the later arrival finished first"
        );
    }
    server.shutdown();
}

#[test]
fn shutdown_under_load_joins_all_workers_and_answers_all_clients() {
    // Shutdown with two device workers mid-cohort must wake every parked
    // worker (the shared condvar broadcast), let in-flight lanes finish,
    // drain already-queued jobs, and join every worker — watchdogged so a
    // deadlock fails the test instead of hanging the suite.
    let Some(server) = start_server_pairs(
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: 1,
            max_batch: 4,
            admit_window_ms: 0,
            ..ServerConfig::default()
        },
        2,
        &[("opensora-sim", "240p-2s")],
    ) else {
        return;
    };
    let addr = server.addr();

    let mut handles = Vec::new();
    for cid in 0..4u64 {
        let req = gen_req("foresight", &format!("shutdown load {cid}"), cid, 30);
        let mut c = Client::connect(&addr).unwrap();
        assert!(c.ping().unwrap());
        handles.push(std::thread::spawn(move || c.call(&req)));
    }
    // Wait until every request is actually in flight (one shared cohort
    // key, max_batch 4 ⇒ all four admit), so none races the stop flag at
    // its enqueue and every answer below must be a served "ok".
    {
        let mut c = Client::connect(&addr).unwrap();
        let t0 = std::time::Instant::now();
        loop {
            let s = c.call(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
            if s.get("lanes_active").unwrap().as_usize().unwrap() >= 4 {
                break;
            }
            assert!(t0.elapsed() < std::time::Duration::from_secs(20), "load never started: {s}");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
    }

    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        server.shutdown();
        let _ = tx.send(());
    });
    assert!(
        rx.recv_timeout(std::time::Duration::from_secs(120)).is_ok(),
        "shutdown under load deadlocked (worker join hung)"
    );

    // Every client got a definitive answer: jobs enqueued before the stop
    // flag are served to completion ("ok"); none may hang or lose its
    // connection mid-request.
    for h in handles {
        let r = h.join().unwrap().expect("connection must outlive shutdown");
        assert_eq!(r.get("status").unwrap().as_str().unwrap(), "ok", "{r}");
    }
}

// ---------------------------------------------------------------------------
// Overload control: bounded admission, deadlines, degradation, shutdown
// drain. All pinned to one device via `start_server_pairs(cfg, 1, ..)`:
// the properties under test are per-queue and the CI re-run at
// FORESIGHT_TEST_DEVICES=2 must not change the topology underneath them.
// ---------------------------------------------------------------------------

fn stats_op() -> Json {
    Json::obj(vec![("op", Json::str("stats"))])
}

/// Poll the `stats` op until `pred` holds; panic with the last snapshot
/// if it never does.
fn wait_stats(c: &mut Client, what: &str, pred: impl Fn(&Json) -> bool) {
    let t0 = std::time::Instant::now();
    loop {
        let s = c.call(&stats_op()).unwrap();
        if pred(&s) {
            return;
        }
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(20),
            "never reached {what}: {s}"
        );
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
}

fn with_deadline(mut req: Json, ms: u64) -> Json {
    if let Json::Obj(ref mut o) = req {
        o.insert("deadline_ms".into(), Json::num(ms as f64));
    }
    req
}

#[test]
fn full_queue_rejects_with_overloaded_and_retry_hint() {
    // max_queue 1 on one device: a long request holds the only lane
    // (max_batch 1), one short request fills the queue, and the next
    // arrival must get the `overloaded` backpressure response instead of
    // queueing — counted in `rejects`, never in `requests`/`errors`.
    let Some(server) = start_server_pairs(
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: 1,
            max_batch: 1,
            admit_window_ms: 0,
            max_queue: 1,
            ..ServerConfig::default()
        },
        1,
        &[("opensora-sim", "240p-2s")],
    ) else {
        return;
    };
    let addr = server.addr();

    let mut c_plug = Client::connect(&addr).unwrap();
    let plug = gen_req("foresight", "overload plug", 1, 40);
    let h_plug = std::thread::spawn(move || c_plug.call(&plug).unwrap());
    let mut c = Client::connect(&addr).unwrap();
    wait_stats(&mut c, "plug in flight", |s| {
        s.get("lanes_active").unwrap().as_usize().unwrap() >= 1
    });

    let mut c_fill = Client::connect(&addr).unwrap();
    let fill = gen_req("foresight", "queued filler", 2, 4);
    let h_fill = std::thread::spawn(move || c_fill.call(&fill).unwrap());
    wait_stats(&mut c, "filler queued", |s| {
        s.get("queue_depth").unwrap().as_usize().unwrap() >= 1
    });

    // Queue at capacity: the probe is answered inline on its connection
    // thread — rejected, never queued — with a clamped drain-time hint.
    let r = c.call(&gen_req("none", "overload probe", 3, 4)).unwrap();
    assert_eq!(r.get("status").unwrap().as_str().unwrap(), "error", "{r}");
    assert!(is_overloaded(&r), "{r}");
    let hint = r.get("retry_after_ms").unwrap().as_f64().unwrap();
    assert!((25.0..=5000.0).contains(&hint), "hint outside clamp range: {r}");
    assert_eq!(r.get("queue_depth").unwrap().as_usize().unwrap(), 1, "{r}");

    // the rejection disturbed neither the plug nor the queued filler
    let r_plug = h_plug.join().unwrap();
    assert_eq!(r_plug.get("status").unwrap().as_str().unwrap(), "ok", "{r_plug}");
    let r_fill = h_fill.join().unwrap();
    assert_eq!(r_fill.get("status").unwrap().as_str().unwrap(), "ok", "{r_fill}");

    let s = c.call(&stats_op()).unwrap();
    assert_eq!(s.get("rejects").unwrap().as_usize().unwrap(), 1, "{s}");
    // a reject is its own ledger: not a request, not an error
    assert_eq!(s.get("requests").unwrap().as_usize().unwrap(), 2, "{s}");
    assert_eq!(s.get("errors").unwrap().as_usize().unwrap(), 0, "{s}");
    assert_eq!(s.get("retires").unwrap().as_usize().unwrap(), 2, "{s}");
    assert_eq!(s.get("deadline_misses").unwrap().as_usize().unwrap(), 0, "{s}");
    assert!(s.get("queue_depth_peak").unwrap().as_usize().unwrap() >= 1, "{s}");
    assert_eq!(s.get("queue_depth").unwrap().as_usize().unwrap(), 0, "{s}");
    server.shutdown();
}

#[test]
fn queued_request_past_deadline_is_answered_at_a_step_boundary() {
    // A queued job whose deadline expires behind a long in-flight request
    // is answered by the boundary sweep while the plug is *still running*
    // — it never occupies a lane, and the miss is accounted as an error.
    let Some(server) = start_server_pairs(
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: 1,
            max_batch: 1,
            admit_window_ms: 0,
            ..ServerConfig::default()
        },
        1,
        &[("opensora-sim", "240p-2s")],
    ) else {
        return;
    };
    let addr = server.addr();

    let mut c_plug = Client::connect(&addr).unwrap();
    let plug = gen_req("foresight", "deadline plug", 1, 40);
    let h_plug = std::thread::spawn(move || c_plug.call(&plug).unwrap());
    let mut c = Client::connect(&addr).unwrap();
    wait_stats(&mut c, "plug in flight", |s| {
        s.get("lanes_active").unwrap().as_usize().unwrap() >= 1
    });

    // deadline 1ms: hopeless long before the plug's 40 steps drain, so
    // the job can never be granted a lane — the queue sweep must answer.
    let r = c.call(&with_deadline(gen_req("none", "doomed", 2, 4), 1)).unwrap();
    assert_eq!(r.get("status").unwrap().as_str().unwrap(), "error", "{r}");
    assert!(
        r.get("deadline_exceeded").unwrap().as_bool().unwrap(),
        "miss must be machine-readable: {r}"
    );
    // answered at a boundary of the in-flight cohort, not after it: the
    // plug (hundreds of ms of schedule left) is still holding its lane
    let s = c.call(&stats_op()).unwrap();
    assert!(
        s.get("lanes_active").unwrap().as_usize().unwrap() >= 1,
        "the miss should have been answered mid-plug: {s}"
    );

    let r_plug = h_plug.join().unwrap();
    assert_eq!(r_plug.get("status").unwrap().as_str().unwrap(), "ok", "{r_plug}");

    let s = c.call(&stats_op()).unwrap();
    assert_eq!(s.get("requests").unwrap().as_usize().unwrap(), 2, "{s}");
    assert_eq!(s.get("errors").unwrap().as_usize().unwrap(), 1, "{s}");
    assert_eq!(s.get("deadline_misses").unwrap().as_usize().unwrap(), 1, "{s}");
    assert_eq!(s.get("retires").unwrap().as_usize().unwrap(), 1, "{s}");
    assert_eq!(s.get("rejects").unwrap().as_usize().unwrap(), 0, "{s}");
    server.shutdown();
}

#[test]
fn inflight_deadline_expiry_frees_the_lane_and_answers_the_client() {
    // A request admitted with a live deadline that expires mid-run is cut
    // short at a step boundary: the client gets the deadline error well
    // before the full schedule would have finished, the lane drains, and
    // the worker keeps serving.
    let Some(server) = start_server_pairs(
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: 1,
            max_batch: 1,
            admit_window_ms: 0,
            ..ServerConfig::default()
        },
        1,
        &[("opensora-sim", "240p-2s")],
    ) else {
        return;
    };
    let addr = server.addr();
    let mut c = Client::connect(&addr).unwrap();

    // Warm + calibrate: the same 40-step request served to completion
    // sets the clock the doomed run's deadline is scaled from.
    let t0 = std::time::Instant::now();
    let warm = c.call(&gen_req("none", "calibrate", 1, 40)).unwrap();
    assert_eq!(warm.get("status").unwrap().as_str().unwrap(), "ok", "{warm}");
    let full = t0.elapsed();

    // Expire about a third of the way through: far past admission (an
    // idle worker admits in microseconds) and far short of completion.
    let deadline_ms = (full.as_millis() as u64 / 3).clamp(15, 1000);
    let t1 = std::time::Instant::now();
    let r = c
        .call(&with_deadline(gen_req("none", "expires midflight", 1, 40), deadline_ms))
        .unwrap();
    let took = t1.elapsed();
    assert_eq!(r.get("status").unwrap().as_str().unwrap(), "error", "{r}");
    assert!(r.get("deadline_exceeded").unwrap().as_bool().unwrap(), "{r}");
    assert!(
        took < full,
        "an expired lane must retire early, not run out its schedule \
         (took {took:?} vs full run {full:?})"
    );

    // lane freed, worker healthy
    wait_stats(&mut c, "lanes drained", |s| {
        s.get("lanes_active").unwrap().as_usize().unwrap() == 0
            && s.get("queue_depth").unwrap().as_usize().unwrap() == 0
    });
    let ok = c.call(&gen_req("none", "recovery", 2, 4)).unwrap();
    assert_eq!(ok.get("status").unwrap().as_str().unwrap(), "ok", "{ok}");

    let s = c.call(&stats_op()).unwrap();
    assert_eq!(s.get("requests").unwrap().as_usize().unwrap(), 3, "{s}");
    assert_eq!(s.get("retires").unwrap().as_usize().unwrap(), 2, "{s}");
    assert_eq!(s.get("errors").unwrap().as_usize().unwrap(), 1, "{s}");
    assert_eq!(s.get("deadline_misses").unwrap().as_usize().unwrap(), 1, "{s}");
    server.shutdown();
}

const TUNED_SPEC: &str = "foresight:n=1,r=2,gamma=0.5";
const FAST_GOOD: &str = "static:n=1,r=3";
const FAST_BAD: &str = "static:n=1,r=6";

/// A tuned profile whose chosen spec has *headroom*: the frontier holds a
/// faster in-budget point (`FAST_GOOD`, 31 dB ≥ the 30 dB budget) and a
/// faster-still out-of-budget one (`FAST_BAD`, 22 dB) the degradation
/// valve must never pick. Autotune-written stores pick the fastest
/// in-budget point as the spec already, making degradation a no-op — this
/// mirrors a hand-tuned store that prefers quality.
fn headroom_store(steps: usize) -> Arc<ProfileStore> {
    let frontier = vec![
        ProfilePoint {
            spec: FAST_BAD.into(),
            wall_s: 0.5,
            reuse_fraction: 0.8,
            psnr: 22.0,
            ssim: 0.80,
            lpips: 0.30,
        },
        ProfilePoint {
            spec: FAST_GOOD.into(),
            wall_s: 1.0,
            reuse_fraction: 0.6,
            psnr: 31.0,
            ssim: 0.92,
            lpips: 0.12,
        },
        ProfilePoint {
            spec: TUNED_SPEC.into(),
            wall_s: 3.0,
            reuse_fraction: 0.3,
            psnr: 38.0,
            ssim: 0.99,
            lpips: 0.02,
        },
    ];
    let mut store = ProfileStore::new();
    for sampler in ["rflow", "ddim"] {
        store.insert(TunedProfile {
            key: ProfileKey {
                model: "opensora-sim".into(),
                bucket: "240p-2s".into(),
                sampler: sampler.into(),
                steps,
            },
            spec: TUNED_SPEC.into(),
            min_psnr: 30.0,
            profile_version: 1,
            frontier: frontier.clone(),
        });
    }
    Arc::new(store)
}

#[test]
fn policy_auto_degrades_under_queue_pressure_within_psnr_budget() {
    const STEPS: usize = 8;
    let Some(server) = start_server_pairs(
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: 1,
            max_batch: 1,
            admit_window_ms: 0,
            degrade_threshold: 1,
            profiles: Some(headroom_store(STEPS)),
            ..ServerConfig::default()
        },
        1,
        &[("opensora-sim", "240p-2s")],
    ) else {
        return;
    };
    let addr = server.addr();

    // Plug the lane and park one filler in the queue: depth ≥ threshold.
    let mut c_plug = Client::connect(&addr).unwrap();
    let plug = gen_req("foresight", "degrade plug", 1, 40);
    let h_plug = std::thread::spawn(move || c_plug.call(&plug).unwrap());
    let mut c = Client::connect(&addr).unwrap();
    wait_stats(&mut c, "plug in flight", |s| {
        s.get("lanes_active").unwrap().as_usize().unwrap() >= 1
    });
    let mut c_fill = Client::connect(&addr).unwrap();
    let fill = gen_req("none", "degrade filler", 2, 4);
    let h_fill = std::thread::spawn(move || c_fill.call(&fill).unwrap());
    wait_stats(&mut c, "filler queued", |s| {
        s.get("queue_depth").unwrap().as_usize().unwrap() >= 1
    });

    // `auto` resolves on the connection thread at parse time, so the swap
    // decision reads the queue depth while the filler is still parked.
    let mut c_probe = Client::connect(&addr).unwrap();
    let probe = gen_req("auto", "degrade probe", 3, STEPS);
    let h_probe = std::thread::spawn(move || c_probe.call(&probe).unwrap());

    let r = h_probe.join().unwrap();
    assert_eq!(r.get("status").unwrap().as_str().unwrap(), "ok", "{r}");
    assert_eq!(r.get("resolved_policy").unwrap().as_str().unwrap(), FAST_GOOD, "{r}");
    assert_eq!(r.get("policy_spec").unwrap().as_str().unwrap(), FAST_GOOD, "{r}");
    assert!(r.get("degraded").unwrap().as_bool().unwrap(), "{r}");
    assert_eq!(r.get("degraded_from").unwrap().as_str().unwrap(), TUNED_SPEC, "{r}");
    assert_eq!(r.get("profile_match").unwrap().as_str().unwrap(), "exact", "{r}");

    let r_plug = h_plug.join().unwrap();
    assert_eq!(r_plug.get("status").unwrap().as_str().unwrap(), "ok", "{r_plug}");
    let r_fill = h_fill.join().unwrap();
    assert_eq!(r_fill.get("status").unwrap().as_str().unwrap(), "ok", "{r_fill}");

    // Pressure off (everything drained): the same request resolves the
    // tuned spec again, undegraded.
    let r2 = c.call(&gen_req("auto", "calm probe", 4, STEPS)).unwrap();
    assert_eq!(r2.get("status").unwrap().as_str().unwrap(), "ok", "{r2}");
    assert_eq!(r2.get("resolved_policy").unwrap().as_str().unwrap(), TUNED_SPEC, "{r2}");
    assert!(!r2.get("degraded").unwrap().as_bool().unwrap(), "{r2}");
    assert!(r2.get("degraded_from").is_none(), "{r2}");

    let s = c.call(&stats_op()).unwrap();
    assert_eq!(s.get("degrade_swaps").unwrap().as_usize().unwrap(), 1, "{s}");
    // the frontier's measured wall delta: 3.0s tuned − 1.0s fast tier
    let headroom = s.get("degrade_headroom_s").unwrap().as_f64().unwrap();
    assert!((1.9..=2.1).contains(&headroom), "headroom {headroom}: {s}");
    assert_eq!(s.get("auto_resolved").unwrap().as_usize().unwrap(), 2, "{s}");
    assert_eq!(s.get("errors").unwrap().as_usize().unwrap(), 0, "{s}");
    server.shutdown();
}

#[test]
fn shutdown_answers_queued_expired_and_rejected_jobs() {
    // Shutdown fired with the full overload mix outstanding — a lane in
    // flight, a normal queued job, a queued job whose deadline cannot be
    // met, and a client rejected at capacity — must give every client a
    // definitive answer and join its workers (watchdogged so a deadlock
    // fails rather than hangs the suite).
    let Some(server) = start_server_pairs(
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: 1,
            max_batch: 1,
            admit_window_ms: 0,
            max_queue: 2,
            ..ServerConfig::default()
        },
        1,
        &[("opensora-sim", "240p-2s")],
    ) else {
        return;
    };
    let addr = server.addr();

    let mut c_plug = Client::connect(&addr).unwrap();
    let plug = gen_req("foresight", "shutdown plug", 1, 60);
    let h_plug = std::thread::spawn(move || c_plug.call(&plug).unwrap());
    let mut c = Client::connect(&addr).unwrap();
    wait_stats(&mut c, "plug in flight", |s| {
        s.get("lanes_active").unwrap().as_usize().unwrap() >= 1
    });

    let mut c_norm = Client::connect(&addr).unwrap();
    let norm = gen_req("none", "queued normal", 2, 4);
    let h_norm = std::thread::spawn(move || c_norm.call(&norm).unwrap());
    wait_stats(&mut c, "normal job queued", |s| {
        s.get("queue_depth").unwrap().as_usize().unwrap() >= 1
    });

    // Deadline 150ms: still live while the probe below arrives (so the
    // queue stays pinned at capacity) but unmeetable — the plug holds the
    // lane for the rest of its ≫150ms schedule, so this job can only ever
    // be answered with the deadline error, swept or drained.
    let mut c_doom = Client::connect(&addr).unwrap();
    let doom = with_deadline(gen_req("none", "queued doomed", 3, 4), 150);
    let h_doom = std::thread::spawn(move || c_doom.call(&doom).unwrap());
    wait_stats(&mut c, "doomed job queued", |s| {
        s.get("queue_depth").unwrap().as_usize().unwrap() >= 2
    });

    // Queue full: rejected at the door.
    let r_rej = c.call(&gen_req("none", "rejected probe", 4, 4)).unwrap();
    assert!(is_overloaded(&r_rej), "{r_rej}");
    let s = c.call(&stats_op()).unwrap();
    assert_eq!(s.get("rejects").unwrap().as_usize().unwrap(), 1, "{s}");

    // Shutdown with all of it outstanding.
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        server.shutdown();
        let _ = tx.send(());
    });
    assert!(
        rx.recv_timeout(std::time::Duration::from_secs(120)).is_ok(),
        "shutdown with queued + expired + rejected jobs deadlocked"
    );

    // Every client got its definitive answer.
    let r_plug = h_plug.join().unwrap();
    assert_eq!(r_plug.get("status").unwrap().as_str().unwrap(), "ok", "{r_plug}");
    let r_norm = h_norm.join().unwrap();
    assert_eq!(r_norm.get("status").unwrap().as_str().unwrap(), "ok", "{r_norm}");
    let r_doom = h_doom.join().unwrap();
    assert_eq!(r_doom.get("status").unwrap().as_str().unwrap(), "error", "{r_doom}");
    assert!(
        r_doom.get("deadline_exceeded").unwrap().as_bool().unwrap(),
        "{r_doom}"
    );
}

#[test]
fn deterministic_across_connections() {
    let Some(server) = start_server(2) else { return };
    let addr = server.addr();
    let run = || {
        let mut c = Client::connect(&addr).unwrap();
        let r = c.call(&gen_req("foresight", "same prompt", 99, 10)).unwrap();
        (
            r.get("computed_units").unwrap().as_f64().unwrap(),
            r.get("reused_units").unwrap().as_f64().unwrap(),
        )
    };
    assert_eq!(run(), run(), "same request must make identical decisions");
    server.shutdown();
}

#[test]
fn poisoned_telemetry_keeps_stats_serving() {
    // A handler that panics while holding the latency reservoir poisons
    // the inner mutex; `OrderedMutex` is poison-tolerant, so the `stats`
    // op must keep serving afterwards instead of cascading the panic.
    // The `__panic` op only exists when this env var is set (see
    // server::handle_line).
    std::env::set_var("FORESIGHT_TEST_PANIC_OP", "1");
    let Some(server) = start_server(1) else { return };
    let addr = server.addr();

    let mut c = Client::connect(&addr).unwrap();
    let r = c.call(&Json::obj(vec![("op", Json::str("__panic"))]));
    assert!(r.is_err(), "the panicking handler should drop the connection, got {r:?}");

    // A fresh connection still gets real answers out of the poisoned
    // reservoir's server.
    let mut c = Client::connect(&addr).unwrap();
    assert!(c.ping().unwrap());
    let stats = c.call(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
    assert_eq!(stats.get("status").unwrap().as_str().unwrap(), "ok", "{stats}");
    assert_eq!(stats.get("latency_samples").unwrap().as_usize().unwrap(), 0);
    server.shutdown();
}

// --- observability: response echoes, Prometheus scrape, trace spans --------

#[test]
fn generate_echoes_queue_wait_and_reuse_fraction() {
    let Some(server) = start_server(1) else { return };
    let mut c = Client::connect(&server.addr()).unwrap();
    let r = c.call(&gen_req("foresight", "timeline probe", 5, 10)).unwrap();
    assert_eq!(r.get("status").unwrap().as_str().unwrap(), "ok", "{r}");

    let qw = r.get("queue_wait_s").unwrap().as_f64().unwrap();
    assert!(qw.is_finite() && qw >= 0.0, "{r}");
    assert_eq!(
        qw,
        r.get("queue_s").unwrap().as_f64().unwrap(),
        "queue_wait_s must alias queue_s exactly: {r}"
    );

    let rf = r.get("reuse_fraction").unwrap().as_f64().unwrap();
    assert!((0.0..=1.0).contains(&rf), "{r}");
    let reused = r.get("reused_units").unwrap().as_f64().unwrap();
    let computed = r.get("computed_units").unwrap().as_f64().unwrap();
    let fallback = r.get("fallback_units").unwrap().as_f64().unwrap();
    assert!(fallback >= 0.0, "{r}");
    if reused + computed > 0.0 {
        assert!(
            (rf - reused / (reused + computed)).abs() < 1e-9,
            "reuse_fraction must match its unit counters: {r}"
        );
    }
    server.shutdown();
}

#[test]
fn metrics_op_renders_prometheus_exposition() {
    let Some(server) = start_server(1) else { return };
    let mut c = Client::connect(&server.addr()).unwrap();
    let ok = c.call(&gen_req("none", "scrape probe", 1, 4)).unwrap();
    assert_eq!(ok.get("status").unwrap().as_str().unwrap(), "ok", "{ok}");

    let m = c.call(&Json::obj(vec![("op", Json::str("metrics"))])).unwrap();
    assert_eq!(m.get("status").unwrap().as_str().unwrap(), "ok", "{m}");
    assert_eq!(
        m.get("content_type").unwrap().as_str().unwrap(),
        "text/plain; version=0.0.4"
    );
    let body = m.get("body").unwrap().as_str().unwrap().to_string();

    // Every line is a HELP/TYPE comment or a parseable foresight_* sample.
    for line in body.lines() {
        if line.is_empty() {
            continue;
        }
        if line.starts_with('#') {
            assert!(
                line.starts_with("# HELP foresight_") || line.starts_with("# TYPE foresight_"),
                "malformed comment line {line:?}"
            );
            continue;
        }
        let (name, value) = line
            .split_once(' ')
            .unwrap_or_else(|| panic!("malformed sample line {line:?}"));
        assert!(name.starts_with("foresight_"), "{line}");
        let v: f64 = value
            .parse()
            .unwrap_or_else(|_| panic!("unparseable value in {line:?}"));
        assert!(v.is_finite(), "{line}");
    }

    // The served request and the ledger's new trace counters all scrape.
    assert!(body.contains("# TYPE foresight_requests gauge"), "{body}");
    assert!(body.contains("\nforesight_requests 1\n") || body.starts_with("foresight_requests 1"), "{body}");
    for key in ["trace_events", "trace_drops", "traces_served", "latency_p99_s", "queue_mean_s"] {
        assert!(
            body.contains(&format!("# TYPE foresight_{key} gauge")),
            "missing family foresight_{key} in:\n{body}"
        );
    }

    // Sharded topology adds per-device families with device labels.
    if test_devices() > 1 {
        for d in 0..test_devices() {
            assert!(
                body.contains(&format!("foresight_device_joins{{device=\"{d}\"}}")),
                "missing device {d} sample in:\n{body}"
            );
        }
    }
    server.shutdown();
}

#[test]
fn trace_spans_one_per_request_and_ordered() {
    let Some(server) = start_server(2) else { return };
    let addr = server.addr();

    // Enable the tracer over the wire. Never disable it here: the tracer
    // is process-global and other tests in this binary may be recording.
    let mut c = Client::connect(&addr).unwrap();
    let t0 = c
        .call(&Json::obj(vec![
            ("op", Json::str("trace")),
            ("enable", Json::Bool(true)),
        ]))
        .unwrap();
    assert_eq!(t0.get("status").unwrap().as_str().unwrap(), "ok", "{t0}");
    assert!(t0.get("enabled").unwrap().as_bool().unwrap(), "{t0}");

    // Staggered sessions with step counts no other test uses, so this
    // test can find its own spans in the shared ring (retire events
    // carry the step total).
    let steps = [13usize, 15, 17];
    let mut handles = Vec::new();
    for (i, &n) in steps.iter().enumerate() {
        handles.push(std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(20 * i as u64));
            let mut c = Client::connect(&addr).unwrap();
            let mut req = gen_req("foresight", &format!("span probe {i}"), i as u64, n);
            if let Json::Obj(ref mut o) = req {
                o.insert("trace".into(), Json::Bool(true));
            }
            c.call(&req).unwrap()
        }));
    }
    let resps: Vec<Json> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    for r in &resps {
        assert_eq!(r.get("status").unwrap().as_str().unwrap(), "ok", "{r}");
        // Timeline ↔ RunStats agreement: the planned branch-0 reuse count
        // never exceeds effective reuses plus cold-cache fallbacks.
        let tl = r.get("reuse_timeline").unwrap().as_arr().unwrap().to_vec();
        assert!(!tl.is_empty(), "trace:true must attach a timeline: {r}");
        let planned = tl
            .iter()
            .filter(|e| e.get("action").and_then(|a| a.as_str()) == Some("reuse"))
            .count() as f64;
        let reused = r.get("reused_units").unwrap().as_f64().unwrap();
        let fallback = r.get("fallback_units").unwrap().as_f64().unwrap();
        assert!(
            planned <= reused + fallback,
            "planned {planned} > reused {reused} + fallback {fallback}: {r}"
        );
        let tl_steps: Vec<usize> = tl
            .iter()
            .map(|e| e.get("step").unwrap().as_usize().unwrap())
            .collect();
        assert!(
            tl_steps.windows(2).all(|w| w[0] <= w[1]),
            "timeline steps out of order: {tl_steps:?}"
        );
    }

    // Drain the ring and reconstruct this test's spans.
    let d = c.call(&Json::obj(vec![("op", Json::str("trace"))])).unwrap();
    assert_eq!(d.get("status").unwrap().as_str().unwrap(), "ok", "{d}");
    let events = d.get("events").unwrap().as_arr().unwrap().to_vec();

    let arg_u64 = |e: &Json, k: &str| e.get("args").and_then(|a| a.get(k)).and_then(|v| v.as_u64());
    let name_of = |e: &Json| e.get("name").and_then(|n| n.as_str()).unwrap_or("").to_string();
    let seq_of = |e: &Json| e.get("seq").and_then(|v| v.as_u64()).unwrap();

    let mut ours: Vec<u64> = Vec::new();
    for e in &events {
        if name_of(e) == "retire" && arg_u64(e, "steps").is_some_and(|s| steps.contains(&(s as usize))) {
            if let Some(id) = arg_u64(e, "trace_id") {
                if id != 0 && !ours.contains(&id) {
                    ours.push(id);
                }
            }
        }
    }
    assert_eq!(ours.len(), 3, "expected one retire per staggered request among {} events", events.len());

    for &id in &ours {
        let evs: Vec<&Json> = events
            .iter()
            .filter(|e| arg_u64(e, "trace_id") == Some(id))
            .collect();
        let ph = |e: &Json| e.get("ph").and_then(|p| p.as_str()).unwrap_or("");
        // Exactly one span per request.
        let begins: Vec<&&Json> = evs.iter().filter(|e| ph(e) == "b").collect();
        let ends: Vec<&&Json> = evs.iter().filter(|e| ph(e) == "e").collect();
        assert_eq!(begins.len(), 1, "one begin for trace {id}");
        assert_eq!(ends.len(), 1, "one end for trace {id}");
        let b = seq_of(begins[0]);
        let e_seq = seq_of(ends[0]);

        // admitted ≤ step(0) < … < finished, in global emission order.
        let admit = evs
            .iter()
            .find(|e| name_of(e) == "admit")
            .unwrap_or_else(|| panic!("no admit event for trace {id}"));
        let retire = evs
            .iter()
            .find(|e| name_of(e) == "retire")
            .unwrap_or_else(|| panic!("no retire event for trace {id}"));
        let mut policies: Vec<&&Json> = evs.iter().filter(|e| name_of(e) == "policy").collect();
        assert!(!policies.is_empty(), "no policy events for trace {id}");
        policies.sort_by_key(|e| seq_of(e));
        assert!(b < seq_of(admit), "begin after admit for trace {id}");
        assert!(
            seq_of(admit) <= seq_of(policies[0]),
            "admit after first policy step for trace {id}"
        );
        assert!(
            seq_of(policies[policies.len() - 1]) < seq_of(retire),
            "policy event after retire for trace {id}"
        );
        assert!(seq_of(retire) < e_seq, "retire after span end for trace {id}");
        // Per-step policy batches arrive in step order.
        let psteps: Vec<u64> = policies.iter().map(|e| arg_u64(e, "step").unwrap()).collect();
        assert!(
            psteps.windows(2).all(|w| w[0] <= w[1]),
            "policy steps out of order for trace {id}: {psteps:?}"
        );
    }
    server.shutdown();
}
