//! Engine integration: full generations over real artifacts under every
//! policy; checks determinism, reuse accounting, quality coupling and the
//! paper's qualitative orderings at small scale.

use std::sync::Arc;

use foresight::config::Manifest;
use foresight::engine::{Engine, HotPath, Request};
use foresight::model::LoadedModel;
use foresight::policy::{self, build_policy};
use foresight::runtime::Runtime;
use foresight::util::stats::mse_f32;

fn engine(model: &str, bucket: &str) -> Option<Engine> {
    let root = Manifest::default_root();
    if !root.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts — run `make artifacts`");
        return None;
    }
    let manifest = Manifest::load(&root).unwrap();
    let rt = Arc::new(Runtime::cpu().unwrap());
    let m = Arc::new(LoadedModel::load(rt, &manifest, model, bucket).unwrap());
    Some(Engine::new(m, manifest.schedule))
}

/// The same loaded model behind both hot-path modes (device-resident vs.
/// seed-era host staging). Skips gracefully when this preset's artifacts
/// are absent.
fn engines_both_modes(model: &str, bucket: &str) -> Option<(Engine, Engine)> {
    let root = Manifest::default_root();
    if !root.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts — run `make artifacts`");
        return None;
    }
    let manifest = Manifest::load(&root).unwrap();
    let rt = Arc::new(Runtime::cpu().unwrap());
    let m = match LoadedModel::load(rt, &manifest, model, bucket) {
        Ok(m) => Arc::new(m),
        Err(e) => {
            eprintln!("SKIP: {model}/{bucket} not loadable: {e:#}");
            return None;
        }
    };
    let dev = Engine::new(m.clone(), manifest.schedule);
    let host = Engine::with_hot_path(m, manifest.schedule, HotPath::Host);
    Some((dev, host))
}

fn run(eng: &Engine, spec: &str, prompt: &str, seed: u64) -> foresight::engine::RunResult {
    run_steps(eng, spec, prompt, seed, None)
}

fn run_steps(
    eng: &Engine,
    spec: &str,
    prompt: &str,
    seed: u64,
    steps: Option<usize>,
) -> foresight::engine::RunResult {
    let info = &eng.model().info;
    let mut pol = build_policy(spec, info, steps.unwrap_or(info.steps)).unwrap();
    let mut req = Request::new(prompt, seed);
    req.steps = steps;
    eng.generate(&req, pol.as_mut(), None).unwrap()
}

#[test]
fn baseline_generation_is_deterministic_and_finite() {
    let Some(eng) = engine("opensora-sim", "240p-2s") else { return };
    let a = run(&eng, "none", "a calm lake at dawn", 7);
    let b = run(&eng, "none", "a calm lake at dawn", 7);
    assert_eq!(a.latents.data, b.latents.data, "same seed+prompt must be bitwise equal");
    assert!(a.latents.data.iter().all(|v| v.is_finite()));
    assert_eq!(a.stats.reused_units, 0);
    assert_eq!(a.stats.cache_peak_bytes, 0);
    // 30 steps × 2 branches × 6 layers × 2 kinds = 720 computed blocks
    assert_eq!(a.stats.computed_units, 720);
}

#[test]
fn different_seeds_or_prompts_change_output() {
    let Some(eng) = engine("opensora-sim", "240p-2s") else { return };
    let a = run(&eng, "none", "a calm lake at dawn", 7);
    let b = run(&eng, "none", "a calm lake at dawn", 8);
    let c = run(&eng, "none", "a storm crashing over cliffs", 7);
    assert_ne!(a.latents.data, b.latents.data);
    assert_ne!(a.latents.data, c.latents.data);
}

#[test]
fn foresight_reuses_and_stays_close_to_baseline() {
    let Some(eng) = engine("opensora-sim", "240p-2s") else { return };
    let base = run(&eng, "none", "a calm lake at dawn", 42);
    let fs = run(&eng, "foresight:n=1,r=2,gamma=0.5", "a calm lake at dawn", 42);

    assert!(fs.stats.reused_units > 0, "foresight must reuse after warmup");
    assert!(fs.stats.computed_units < base.stats.computed_units);
    assert_eq!(fs.stats.fallback_units, 0, "warmup fills the cache before reuse");

    // quality coupling: reused generation stays near the baseline output
    let mse = mse_f32(&base.latents.data, &fs.latents.data);
    let var = {
        let m: f32 = base.latents.data.iter().sum::<f32>() / base.latents.data.len() as f32;
        base.latents.data.iter().map(|v| (v - m).powi(2)).sum::<f32>()
            / base.latents.data.len() as f32
    };
    assert!(
        mse < var as f64,
        "foresight output diverged beyond signal variance: mse={mse}, var={var}"
    );

    // thresholds (λ) exist for every (layer, kind, branch)
    let th = fs.thresholds.expect("foresight exposes thresholds");
    assert_eq!(th.len(), 6 * 2 * 2);
    assert!(th.values().all(|&l| l.is_finite() && l >= 0.0));
}

#[test]
fn gamma_strictness_orders_reuse_and_quality() {
    let Some(eng) = engine("opensora-sim", "240p-2s") else { return };
    let base = run(&eng, "none", "a quiet library hall", 5);
    // absurdly strict threshold → reuse almost never fires outside warmup
    let strict = run(&eng, "foresight:gamma=0.0000000001", "a quiet library hall", 5);
    let lax = run(&eng, "foresight:gamma=2.0", "a quiet library hall", 5);
    assert!(strict.stats.reused_units <= lax.stats.reused_units);
    let mse_strict = mse_f32(&base.latents.data, &strict.latents.data);
    let mse_lax = mse_f32(&base.latents.data, &lax.latents.data);
    assert!(
        mse_strict <= mse_lax * 1.05 + 1e-9,
        "stricter gamma must not be farther from baseline: {mse_strict} vs {mse_lax}"
    );
}

#[test]
fn all_policies_run_and_account_consistently() {
    let Some(eng) = engine("opensora-sim", "240p-2s") else { return };
    let info = eng.model().info.clone();
    let sites_coarse = info.layers * 2;
    let sites_fine = info.layers * 2 * 3;
    for spec in ["none", "static", "foresight", "delta-dit", "tgate", "pab"] {
        let r = run(&eng, spec, "a red vintage car on a mountain road", 9);
        assert!(r.latents.data.iter().all(|v| v.is_finite()), "{spec}: non-finite");
        let total = r.stats.computed_units + r.stats.reused_units;
        let pol = build_policy(spec, &info, info.steps).unwrap();
        let per_step = match pol.granularity() {
            policy::Granularity::Coarse => sites_coarse,
            policy::Granularity::Fine => sites_fine,
        };
        assert_eq!(
            total as usize,
            info.steps * 2 * per_step,
            "{spec}: unit accounting mismatch"
        );
        // reuse map covers branch 0
        assert_eq!(r.reuse_map.len(), info.steps, "{spec}");
        assert!(r.reuse_map.iter().all(|row| row.len() == per_step), "{spec}");
    }
}

#[test]
fn reuse_speeds_up_wall_clock() {
    let Some(eng) = engine("opensora-sim", "240p-2s") else { return };
    // warm both paths once (compile caches, allocators)
    run(&eng, "none", "warmup", 1);
    let base = run(&eng, "none", "a bustling night market at dusk", 3);
    let fast = run(&eng, "static:n=2,r=3", "a bustling night market at dusk", 3);
    assert!(
        fast.stats.wall_s < base.stats.wall_s,
        "static reuse should beat baseline: {} vs {}",
        fast.stats.wall_s,
        base.stats.wall_s
    );
}

#[test]
fn coarse_cache_is_2_entries_per_layer_fine_caches_more() {
    let Some(eng) = engine("opensora-sim", "240p-2s") else { return };
    let fs = run(&eng, "foresight", "memory accounting prompt", 11);
    assert!((fs.stats.cache_entries_per_layer - 2.0).abs() < 1e-9);
    let pab = run(&eng, "pab", "memory accounting prompt", 11);
    assert!(
        pab.stats.cache_entries_per_layer > fs.stats.cache_entries_per_layer,
        "fine-grained PAB must cache more entries per layer"
    );
}

#[test]
fn per_step_latency_drops_on_reuse_steps() {
    let Some(eng) = engine("opensora-sim", "240p-2s") else { return };
    let r = run(&eng, "static:n=1,r=2", "latency shape prompt", 13);
    // odd steps reuse everything → must be faster than even (compute) steps
    let compute_avg: f64 = r.stats.per_step_s.iter().step_by(2).sum::<f64>()
        / r.stats.per_step_s.iter().step_by(2).count() as f64;
    let reuse_avg: f64 = r.stats.per_step_s.iter().skip(1).step_by(2).sum::<f64>()
        / r.stats.per_step_s.iter().skip(1).step_by(2).count() as f64;
    assert!(
        reuse_avg < compute_avg,
        "reuse steps should be cheaper: {reuse_avg} vs {compute_avg}"
    );
}

#[test]
fn device_and_host_hot_paths_are_equivalent_for_both_samplers() {
    // The satellite equivalence check: the resident-latent loop (fused
    // sampler stepping + fused MSE + fused CFG combine + persistent branch
    // worker) must reproduce the host staging to ≤1e-6 per element for
    // every shipped policy, for the rflow preset (opensora) AND the DDIM
    // preset (latte), with identical reuse decisions.
    //
    // Known sensitivity if this ever fails: (a) device drift MSE (XLA f32
    // reduce) and host mse_f32 (f64 accumulation) agree to ~1e-6, so a
    // Foresight δ landing within that band of γλ could flip one decision
    // — diagnose via the reuse_map assert firing first; (b) an XLA build
    // that reassociates the fused step math would widen the latent error
    // — diagnose via `none` failing too.
    let cases = [("opensora-sim", "240p-2s", None), ("latte-sim", "512sq-2s", Some(12))];
    for (model, bucket, steps) in cases {
        let Some((dev, host)) = engines_both_modes(model, bucket) else { continue };
        for spec in ["none", "static:n=1,r=2", "foresight:n=1,r=2,gamma=0.5"] {
            let d = run_steps(&dev, spec, "hot path equivalence prompt", 21, steps);
            let h = run_steps(&host, spec, "hot path equivalence prompt", 21, steps);
            assert_eq!(d.reuse_map, h.reuse_map, "{model}/{spec}: decisions diverged");
            if let Some((i, a, b)) =
                foresight::bench_support::first_latent_mismatch(&d.latents.data, &h.latents.data, 1e-6)
            {
                panic!("{model}/{spec}: latent {i} diverged: device {a} vs host {b}");
            }
            assert!(
                d.stats.d2h_bytes < h.stats.d2h_bytes,
                "{model}/{spec}: device path must download less than host staging \
                 ({} vs {})",
                d.stats.d2h_bytes,
                h.stats.d2h_bytes
            );
            assert!(
                d.stats.h2d_bytes < h.stats.h2d_bytes,
                "{model}/{spec}: device path must upload less than host staging \
                 ({} vs {})",
                d.stats.h2d_bytes,
                h.stats.h2d_bytes
            );
        }
    }
}

#[test]
fn resident_loop_steady_state_traffic_is_scalar_sized() {
    // Tentpole acceptance: once the request is set up, the resident loop's
    // recurring bus traffic is scalar-sized. Differencing two baseline
    // runs at different step counts cancels the request constants (text,
    // initial latent, final download): what remains per step is one 4-byte
    // timestep scalar plus the sampler coefficient (4 bytes for rflow) —
    // ~8 bytes/step up and exactly 0 bytes/step down for a non-measuring
    // policy. The engine's meters are cross-checked against the runtime's
    // ground-truth TransferStats.
    let root = Manifest::default_root();
    if !root.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts — run `make artifacts`");
        return;
    }
    let manifest = Manifest::load(&root).unwrap();
    let rt = Arc::new(Runtime::cpu().unwrap());
    let m = Arc::new(LoadedModel::load(rt.clone(), &manifest, "opensora-sim", "240p-2s").unwrap());
    let eng = Engine::new(m, manifest.schedule);

    let mut measured = Vec::new();
    for steps in [8usize, 24] {
        let before = rt.transfer_stats().snapshot();
        let r = run_steps(&eng, "none", "steady state prompt", 5, Some(steps));
        let delta = rt.transfer_stats().snapshot().delta_since(&before);
        assert_eq!(delta.h2d_bytes, r.stats.h2d_bytes, "h2d meter mismatch at {steps} steps");
        assert_eq!(delta.d2h_bytes, r.stats.d2h_bytes, "d2h meter mismatch at {steps} steps");
        assert_eq!(delta.h2d_calls, r.stats.h2d_calls, "h2d call meter mismatch");
        assert_eq!(delta.d2h_calls, r.stats.d2h_calls, "d2h call meter mismatch");
        measured.push(r.stats);
    }
    let (h2d_per_step, d2h_per_step) =
        foresight::bench_support::steady_state_bytes_per_step(&measured[0], &measured[1]);
    assert!(
        h2d_per_step <= 16.0,
        "steady-state h2d should be scalar-sized (~8 B/step for rflow), got {h2d_per_step}"
    );
    assert_eq!(
        d2h_per_step, 0.0,
        "a non-measuring policy must download nothing per step in steady state"
    );

    // A measuring policy adds only 4-byte drift scalars on top: per step,
    // total d2h beyond the one final latent download is bounded by 4 bytes
    // per (layer, kind, branch) site.
    let short = run_steps(&eng, "foresight:n=1,r=2,gamma=0.5", "steady fs", 5, Some(8));
    let long = run_steps(&eng, "foresight:n=1,r=2,gamma=0.5", "steady fs", 5, Some(24));
    let (fs_h2d, _) =
        foresight::bench_support::steady_state_bytes_per_step(&short.stats, &long.stats);
    assert!(
        fs_h2d <= 16.0,
        "measuring policies upload no extra steady-state bytes, got {fs_h2d}"
    );
    let [f, p, c] = eng.model().latent_dims();
    let final_bytes = (f * p * c * 4) as u64;
    let sites = eng.model().info.layers * 2 * 2; // (layer, kind, branch)
    let meas_per_step = (long.stats.d2h_bytes - final_bytes) as f64 / 24.0;
    assert!(
        meas_per_step <= (sites * 4) as f64,
        "foresight per-step d2h must be ≤4 bytes per measured site \
         ({sites} sites), got {meas_per_step}"
    );
}

#[test]
fn device_hot_path_slashes_foresight_transfers_and_cache() {
    let Some((dev, host)) = engines_both_modes("opensora-sim", "240p-2s") else { return };
    let d = run(&dev, "foresight", "transfer accounting prompt", 4);
    let h = run(&host, "foresight", "transfer accounting prompt", 4);
    // ≥10× fewer device→host bytes per step (acceptance criterion): the
    // F·P·D·4 per-site measurement downloads collapse to 4-byte scalars.
    let reduction = h.stats.d2h_bytes_per_step() / d.stats.d2h_bytes_per_step();
    assert!(
        reduction >= 10.0,
        "expected ≥10x d2h reduction, got {reduction:.1}x \
         (host {} B/step, device {} B/step)",
        h.stats.d2h_bytes_per_step(),
        d.stats.d2h_bytes_per_step()
    );
    // Dropping the host mirrors halves the measured cache footprint.
    let ratio = h.stats.cache_peak_bytes as f64 / d.stats.cache_peak_bytes as f64;
    assert!(
        (ratio - 2.0).abs() < 0.05,
        "expected host-mode cache ≈2x device-mode cache, got {ratio:.2}x"
    );
}

#[test]
fn generate_batch_matches_sequential_device_path() {
    // Tentpole acceptance at the engine level: a micro-batch of requests —
    // even under *different* policies, so one lane reuses while a neighbor
    // recomputes — reproduces each request's sequential device run:
    // identical decisions, identical unit/byte accounting (the as-if byte
    // model), latents to ≤1e-6 (elementwise-identical in practice).
    let Some(eng) = engine("opensora-sim", "240p-2s") else { return };
    let info = eng.model().info.clone();
    let steps = 10usize;
    let specs = ["foresight:n=1,r=2,gamma=0.5", "static:n=2,r=3", "none"];
    let prompts = ["a calm lake at dawn", "a storm crashing over cliffs", "a quiet library"];

    let mut reqs = Vec::new();
    let mut pols = Vec::new();
    for (i, (spec, prompt)) in specs.iter().zip(prompts).enumerate() {
        let mut r = Request::new(prompt, 40 + i as u64);
        r.steps = Some(steps);
        reqs.push(r);
        pols.push(build_policy(spec, &info, steps).unwrap());
    }
    let batch = eng.generate_batch(&reqs, &mut pols).unwrap();
    assert_eq!(batch.len(), 3);

    for (lane, (spec, prompt)) in specs.iter().zip(prompts).enumerate() {
        let seq = run_steps(&eng, spec, prompt, 40 + lane as u64, Some(steps));
        let b = &batch[lane];
        assert_eq!(b.reuse_map, seq.reuse_map, "lane {lane} ({spec}): decisions diverged");
        let mismatch = foresight::bench_support::first_latent_mismatch(
            &b.latents.data,
            &seq.latents.data,
            1e-6,
        );
        if let Some((i, a, c)) = mismatch {
            panic!("lane {lane} ({spec}): latent {i} diverged: batch {a} vs sequential {c}");
        }
        assert_eq!(b.stats.computed_units, seq.stats.computed_units, "lane {lane}");
        assert_eq!(b.stats.reused_units, seq.stats.reused_units, "lane {lane}");
        assert_eq!(b.stats.fallback_units, seq.stats.fallback_units, "lane {lane}");
        // the as-if byte model: per-request meters equal the standalone run
        assert_eq!(b.stats.h2d_bytes, seq.stats.h2d_bytes, "lane {lane}: h2d budget");
        assert_eq!(b.stats.d2h_bytes, seq.stats.d2h_bytes, "lane {lane}: d2h budget");
        assert_eq!(b.stats.cache_peak_bytes, seq.stats.cache_peak_bytes, "lane {lane}");
        assert_eq!(b.stats.per_step_s.len(), steps, "lane {lane}");
    }
}

#[test]
fn generate_batch_rejects_incompatible_requests() {
    let Some(eng) = engine("opensora-sim", "240p-2s") else { return };
    let info = eng.model().info.clone();
    let mk_pols = |n: usize, steps: usize| -> Vec<Box<dyn policy::ReusePolicy>> {
        (0..n).map(|_| build_policy("none", &info, steps).unwrap()).collect()
    };
    fn expect_fail(r: anyhow::Result<Vec<foresight::engine::RunResult>>, what: &str) -> String {
        match r {
            Err(e) => e.to_string(),
            Ok(_) => panic!("{what}: unexpectedly succeeded"),
        }
    }

    // mismatched step counts
    let mut a = Request::new("x", 1);
    a.steps = Some(8);
    let mut b = Request::new("y", 2);
    b.steps = Some(10);
    let mut pols = mk_pols(2, 8);
    let err = expect_fail(eng.generate_batch(&[a.clone(), b], &mut pols), "mixed steps");
    assert!(err.contains("steps"), "{err}");

    // mismatched cfg scales
    let mut c = Request::new("z", 3);
    c.steps = Some(8);
    c.cfg_scale = Some(3.0);
    let mut pols = mk_pols(2, 8);
    let err = expect_fail(eng.generate_batch(&[a.clone(), c], &mut pols), "mixed cfg");
    assert!(err.contains("cfg_scale"), "{err}");

    // request/policy arity mismatch
    let mut pols = mk_pols(1, 8);
    let err = expect_fail(
        eng.generate_batch(&[a.clone(), a.clone()], &mut pols),
        "request/policy arity mismatch",
    );
    assert!(err.contains("policies"), "{err}");

    // empty batch is a no-op, batch of one falls back to the single path
    assert!(eng.generate_batch(&[], &mut []).unwrap().is_empty());
    let mut pols = mk_pols(1, 8);
    let one = eng.generate_batch(&[a], &mut pols).unwrap();
    assert_eq!(one.len(), 1);
    let seq = run_steps(&eng, "none", "x", 1, Some(8));
    assert_eq!(one[0].latents.data, seq.latents.data, "B=1 must equal the single path");
}

#[test]
fn step_override_is_respected() {
    let Some(eng) = engine("opensora-sim", "240p-2s") else { return };
    let info = eng.model().info.clone();
    let mut pol = build_policy("none", &info, 10).unwrap();
    let mut req = Request::new("short run", 2);
    req.steps = Some(10);
    let r = eng.generate(&req, pol.as_mut(), None).unwrap();
    assert_eq!(r.stats.per_step_s.len(), 10);
    assert_eq!(r.stats.computed_units, 10 * 2 * 12);
}

#[test]
fn session_cohort_staggered_mixed_steps_matches_standalone() {
    // Continuous-batching acceptance at the engine level (property-style,
    // fig18-oracle tolerance): a cohort where request B is admitted k
    // steps after request A is already in flight — with mixed step
    // counts, CFG scales and policies — must produce, for every request,
    // latents ≤1e-6 vs that request run standalone, with identical reuse
    // decisions and identical per-request transfer meters.
    use foresight::engine::{step_many_refs, Session};
    use foresight::util::proptest::proptest_cases;
    use std::panic::AssertUnwindSafe;

    let Some(eng) = engine("opensora-sim", "240p-2s") else { return };
    let info = eng.model().info.clone();
    let eng = AssertUnwindSafe(&eng);
    let info = AssertUnwindSafe(&info);
    let specs = [
        "foresight:n=1,r=2,gamma=0.5",
        "static:n=1,r=2",
        "none",
    ];

    proptest_cases(3, |g| {
        let eng: &foresight::engine::Engine = *eng;
        let info: &foresight::config::ModelInfo = *info;
        let steps_a = g.usize_in(6..=9);
        let steps_b = g.usize_in(4..=7);
        let offset = g.usize_in(1..=3); // steps A runs alone before B joins
        let spec_a = *g.pick(&specs);
        let spec_b = *g.pick(&specs);
        let cfg_b = if g.bool() { Some(3.5) } else { None };

        let mut ra = Request::new("staggered lane a", 101);
        ra.steps = Some(steps_a);
        let mut rb = Request::new("staggered lane b", 202);
        rb.steps = Some(steps_b);
        rb.cfg_scale = cfg_b;

        // Standalone oracles.
        let solo_a = run_request(eng, spec_a, &ra, info);
        let solo_b = run_request(eng, spec_b, &rb, info);

        // Cohort: A steps alone, then B joins mid-flight; each retires on
        // its own schedule.
        let mut sa = eng
            .admit(&ra, build_policy(spec_a, info, steps_a).unwrap())
            .unwrap();
        for _ in 0..offset {
            step_many_refs(&mut [&mut sa]).unwrap();
        }
        let mut sb = eng
            .admit(&rb, build_policy(spec_b, info, steps_b).unwrap())
            .unwrap();
        let mut joined = false;
        while !(sa.is_done() && sb.is_done()) {
            let mut refs: Vec<&mut Session> = Vec::new();
            if !sa.is_done() {
                refs.push(&mut sa);
            }
            if !sb.is_done() {
                refs.push(&mut sb);
            }
            joined |= refs.len() == 2;
            step_many_refs(&mut refs).unwrap();
        }
        assert!(joined, "cohort never actually shared a pass");
        assert!(sa.peak_lanes() >= 2 && sb.peak_lanes() >= 2);
        let got_a = sa.finish().unwrap();
        let got_b = sb.finish().unwrap();

        for (lane, (got, solo)) in [("a", (&got_a, &solo_a)), ("b", (&got_b, &solo_b))] {
            assert_eq!(got.reuse_map, solo.reuse_map, "lane {lane}: decisions diverged");
            assert_eq!(
                (got.stats.computed_units, got.stats.reused_units, got.stats.fallback_units),
                (solo.stats.computed_units, solo.stats.reused_units, solo.stats.fallback_units),
                "lane {lane}: unit counters diverged"
            );
            assert_eq!(got.stats.h2d_bytes, solo.stats.h2d_bytes, "lane {lane}: h2d budget");
            assert_eq!(got.stats.d2h_bytes, solo.stats.d2h_bytes, "lane {lane}: d2h budget");
            assert_eq!(
                got.stats.cache_peak_bytes, solo.stats.cache_peak_bytes,
                "lane {lane}: cache footprint"
            );
            let mismatch = foresight::bench_support::first_latent_mismatch(
                &got.latents.data,
                &solo.latents.data,
                1e-6,
            );
            assert!(
                mismatch.is_none(),
                "lane {lane}: cohort latents diverged from standalone \
                 (first mismatch: {mismatch:?})"
            );
        }
    });
}

fn run_request(
    eng: &foresight::engine::Engine,
    spec: &str,
    req: &Request,
    info: &foresight::config::ModelInfo,
) -> foresight::engine::RunResult {
    let steps = req.steps.unwrap_or(info.steps);
    let mut pol = build_policy(spec, info, steps).unwrap();
    eng.generate(req, pol.as_mut(), None).unwrap()
}
