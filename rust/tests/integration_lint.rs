//! Self-lint: `foresight lint` run over this crate's own tree must come
//! back clean. This is the same gate as the CI lint leg, wired into
//! `cargo test` so a violation (or a stale allowlist row) fails the suite
//! even where CI cannot build (no artifacts needed).

use std::path::Path;

use foresight::analysis::lint::{collect_sources, run_all, Allowlist};

fn crate_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn tree_has_no_blocking_findings() {
    let files = collect_sources(&crate_root().join("src")).expect("collect rust/src");
    let allow = Allowlist::load(&crate_root().join("lint.allow")).expect("parse lint.allow");
    let blocking: Vec<String> = run_all(&files)
        .into_iter()
        .filter(|f| allow.permits(f).is_none())
        .map(|f| f.to_string())
        .collect();
    assert!(
        blocking.is_empty(),
        "non-allowlisted lint findings (fix them or add a justified rust/lint.allow row):\n{}",
        blocking.join("\n")
    );
}

#[test]
fn allowlist_has_no_stale_rows() {
    // The CLI only warns about rows that stopped matching; the test suite
    // makes staleness a hard failure so exemptions cannot outlive the
    // code they excused.
    let files = collect_sources(&crate_root().join("src")).expect("collect rust/src");
    let allow = Allowlist::load(&crate_root().join("lint.allow")).expect("parse lint.allow");
    let mut used = vec![false; allow.entries.len()];
    for f in run_all(&files) {
        if let Some(i) = allow.permits(&f) {
            used[i] = true;
        }
    }
    let stale: Vec<String> = allow
        .entries
        .iter()
        .zip(&used)
        .filter(|(_, u)| !**u)
        .map(|(e, _)| format!("lint.allow:{}: {}|{}|{}", e.line, e.pass, e.file_suffix, e.pattern))
        .collect();
    assert!(stale.is_empty(), "allowlist rows match nothing — remove them:\n{}", stale.join("\n"));
}
