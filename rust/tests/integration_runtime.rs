//! Runtime integration: load real artifacts, execute pieces, cross-check
//! numerics against the Python oracle fixtures where available.
//!
//! Requires `make artifacts` to have run (skipped otherwise, loudly).

use std::path::Path;
use std::sync::Arc;

use foresight::config::Manifest;
use foresight::model::{BlockKind, LoadedModel};
use foresight::runtime::{HostTensor, Runtime};
use foresight::util::prng::Rng;

fn artifacts_root() -> Option<std::path::PathBuf> {
    let root = Manifest::default_root();
    if root.join("manifest.json").exists() {
        Some(root)
    } else {
        eprintln!("SKIP: no artifacts at {} — run `make artifacts`", root.display());
        None
    }
}

fn load_model(rt: &Arc<Runtime>, model: &str, bucket: &str) -> Option<LoadedModel> {
    let root = artifacts_root()?;
    let manifest = Manifest::load(&root).expect("manifest parses");
    Some(LoadedModel::load(rt.clone(), &manifest, model, bucket).expect("model loads"))
}

#[test]
fn manifest_loads_and_lists_models() {
    let Some(root) = artifacts_root() else { return };
    let m = Manifest::load(&root).unwrap();
    for name in ["opensora-sim", "latte-sim", "cogvideox-sim", "analysis"] {
        assert!(m.models.contains_key(name), "missing model {name}");
    }
    let os = m.model("opensora-sim").unwrap();
    assert_eq!(os.sampler.name(), "rflow");
    assert!(os.buckets.contains_key("240p-2s"));
}

#[test]
fn full_piece_pipeline_executes_with_correct_shapes() {
    let rt = Arc::new(Runtime::cpu().unwrap());
    let Some(m) = load_model(&rt, "opensora-sim", "240p-2s") else { return };
    let [f, p, d] = m.state_dims();
    let [_, _, c_lat] = m.latent_dims();

    let mut rng = Rng::new(42);
    let x = HostTensor::new(vec![f, p, c_lat], rng.normal_vec(f * p * c_lat));
    let raw = HostTensor::new(
        vec![m.info.text_len, m.info.d_text],
        rng.normal_vec(m.info.text_len * m.info.d_text),
    );

    let c = m.t_embed(500.0).unwrap();
    assert_eq!(c.dims(), &[d]);

    let text = m.text_proj(&raw).unwrap();
    assert_eq!(text.dims(), &[m.info.text_len, d]);

    let tk = m.text_k(0, BlockKind::Spatial, &text).unwrap();
    let tv = m.text_v(0, BlockKind::Spatial, &text).unwrap();
    assert_eq!(tk.dims(), &[m.info.text_len, d]);

    let xd = rt.upload_tensor(&x).unwrap();
    let mut h = m.embed(&xd).unwrap();
    assert_eq!(h.dims(), &[f, p, d]);

    for layer in 0..m.info.layers {
        for kind in BlockKind::ALL {
            let tk = m.text_k(layer, kind, &text).unwrap();
            let tv = m.text_v(layer, kind, &text).unwrap();
            h = m.block_full(layer, kind, &h, &c, &tk, &tv).unwrap();
        }
    }
    let eps = m.final_proj(&h, &c).unwrap();
    assert_eq!(eps.dims(), &[f, p, c_lat]);

    let host = rt.download(&eps).unwrap();
    assert!(host.data.iter().all(|v| v.is_finite()), "non-finite output");
    let std = {
        let mean: f32 = host.data.iter().sum::<f32>() / host.data.len() as f32;
        (host.data.iter().map(|v| (v - mean).powi(2)).sum::<f32>()
            / host.data.len() as f32)
            .sqrt()
    };
    assert!(std > 0.05 && std < 100.0, "implausible output std {std}");
    let _ = tv;
}

#[test]
fn sub_blocks_compose_to_full_block() {
    let rt = Arc::new(Runtime::cpu().unwrap());
    let Some(m) = load_model(&rt, "opensora-sim", "240p-2s") else { return };
    let [f, p, d] = m.state_dims();
    let mut rng = Rng::new(7);
    let h0 = rt
        .upload(&rng.normal_vec(f * p * d), &[f, p, d])
        .unwrap();
    let c = m.t_embed(250.0).unwrap();
    let raw = HostTensor::new(
        vec![m.info.text_len, m.info.d_text],
        rng.normal_vec(m.info.text_len * m.info.d_text),
    );
    let text = m.text_proj(&raw).unwrap();

    for kind in BlockKind::ALL {
        let tk = m.text_k(2, kind, &text).unwrap();
        let tv = m.text_v(2, kind, &text).unwrap();
        let full = m.block_full(2, kind, &h0, &c, &tk, &tv).unwrap();
        let h1 = m.block_attn(2, kind, &h0, &c).unwrap();
        let h2 = m.block_cross(2, kind, &h1, &tk, &tv).unwrap();
        let h3 = m.block_mlp(2, kind, &h2, &c).unwrap();

        let a = rt.download(&full).unwrap();
        let b = rt.download(&h3).unwrap();
        let max_diff = a
            .data
            .iter()
            .zip(&b.data)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(
            max_diff < 1e-4,
            "{:?}: sub-block composition diverges from full block: {max_diff}",
            kind
        );
    }
}

#[test]
fn elementwise_add_sub_roundtrip() {
    let rt = Arc::new(Runtime::cpu().unwrap());
    let Some(m) = load_model(&rt, "opensora-sim", "240p-2s") else { return };
    let [f, p, d] = m.state_dims();
    let mut rng = Rng::new(3);
    let av = rng.normal_vec(f * p * d);
    let bv = rng.normal_vec(f * p * d);
    let a = rt.upload(&av, &[f, p, d]).unwrap();
    let b = rt.upload(&bv, &[f, p, d]).unwrap();
    let sum = m.add(&a, &b).unwrap();
    let back = m.sub(&sum, &b).unwrap();
    let host = rt.download(&back).unwrap();
    let max_diff = host
        .data
        .iter()
        .zip(&av)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-5, "add/sub roundtrip error {max_diff}");
}

#[test]
fn concurrent_block_execution_is_safe() {
    let rt = Arc::new(Runtime::cpu().unwrap());
    let Some(m) = load_model(&rt, "opensora-sim", "240p-2s") else { return };
    let m = Arc::new(m);
    let [f, p, d] = m.state_dims();

    let mut handles = Vec::new();
    for tid in 0..4u64 {
        let m = Arc::clone(&m);
        let rt = Arc::clone(&rt);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + tid);
            let c = m.t_embed(100.0 + tid as f32).unwrap();
            let raw = HostTensor::new(
                vec![m.info.text_len, m.info.d_text],
                rng.normal_vec(m.info.text_len * m.info.d_text),
            );
            let text = m.text_proj(&raw).unwrap();
            let tk = m.text_k(0, BlockKind::Spatial, &text).unwrap();
            let tv = m.text_v(0, BlockKind::Spatial, &text).unwrap();
            let mut h = rt.upload(&rng.normal_vec(f * p * d), &[f, p, d]).unwrap();
            for _ in 0..5 {
                h = m.block_full(0, BlockKind::Spatial, &h, &c, &tk, &tv).unwrap();
            }
            let out = rt.download(&h).unwrap();
            assert!(out.data.iter().all(|v| v.is_finite()));
        }));
    }
    for h in handles {
        h.join().expect("worker thread panicked");
    }
}

#[test]
fn deterministic_execution_same_inputs_same_outputs() {
    let rt = Arc::new(Runtime::cpu().unwrap());
    let Some(m) = load_model(&rt, "opensora-sim", "240p-2s") else { return };
    let [f, p, d] = m.state_dims();
    let mut rng = Rng::new(11);
    let hv = rng.normal_vec(f * p * d);
    let c = m.t_embed(42.0).unwrap();
    let raw = HostTensor::new(
        vec![m.info.text_len, m.info.d_text],
        rng.normal_vec(m.info.text_len * m.info.d_text),
    );
    let text = m.text_proj(&raw).unwrap();
    let tk = m.text_k(1, BlockKind::Temporal, &text).unwrap();
    let tv = m.text_v(1, BlockKind::Temporal, &text).unwrap();

    let run = || {
        let h = rt.upload(&hv, &[f, p, d]).unwrap();
        let out = m
            .block_full(1, BlockKind::Temporal, &h, &c, &tk, &tv)
            .unwrap();
        rt.download(&out).unwrap().data
    };
    assert_eq!(run(), run(), "block execution must be deterministic");
}

#[test]
fn state_mse_matches_host_reference_on_real_activations() {
    // The device-side drift reduction (Foresight Eq. 5/6) against the host
    // oracle, at full state size, on realistic block outputs — and at the
    // advertised 4-bytes-per-measurement transfer cost.
    let rt = Arc::new(Runtime::cpu().unwrap());
    let Some(m) = load_model(&rt, "opensora-sim", "240p-2s") else { return };
    let [f, p, d] = m.state_dims();
    let mut rng = Rng::new(17);
    let av = rng.normal_vec(f * p * d);
    let bv = rng.normal_vec(f * p * d);
    let a = rt.upload(&av, &[f, p, d]).unwrap();
    let b = rt.upload(&bv, &[f, p, d]).unwrap();

    let before = rt.transfer_stats().snapshot();
    let dev = m.state_mse(&a, &b).unwrap();
    let delta = rt.transfer_stats().snapshot().delta_since(&before);
    assert_eq!(delta.d2h_bytes, 4, "state_mse must download exactly one f32");

    let host = foresight::util::stats::mse_f32(&av, &bv);
    let tol = 1e-5 * (1.0 + host.abs());
    assert!(
        (dev - host).abs() < tol,
        "device mse {dev} vs host {host} (n={})",
        f * p * d
    );
    assert_eq!(m.state_mse(&a, &a).unwrap(), 0.0);
}
