//! Multi-device sharding integration: device-pool isolation, session
//! migration correctness (the steal-correctness property), and a
//! server-level work steal observed through the wire telemetry.

use std::panic::AssertUnwindSafe;
use std::sync::Arc;

use foresight::bench_support::first_latent_mismatch;
use foresight::config::Manifest;
use foresight::engine::{Engine, HotPath, Request, RunResult};
use foresight::model::LoadedModel;
use foresight::policy::{build_policy, ReusePolicy};
use foresight::runtime::DevicePool;
use foresight::server::{Client, EngineRegistry, Server, ServerConfig};
use foresight::util::json::Json;
use foresight::util::proptest::{prop_assert, proptest_cases};

const MODEL: (&str, &str) = ("opensora-sim", "240p-2s");

fn artifacts_present() -> bool {
    let ok = Manifest::default_root().join("manifest.json").exists();
    if !ok {
        eprintln!("SKIP: no artifacts — run `make artifacts`");
    }
    ok
}

/// Two engines for the same (model, bucket) on two independent runtime
/// replicas — the minimal migration topology.
fn two_engines() -> anyhow::Result<Vec<Arc<Engine>>> {
    let manifest = Manifest::load(&Manifest::default_root())?;
    let pool = DevicePool::cpu(2)?;
    let mut engines = Vec::with_capacity(2);
    for rt in pool.devices() {
        let lm = Arc::new(LoadedModel::load(rt.clone(), &manifest, MODEL.0, MODEL.1)?);
        engines.push(Arc::new(Engine::with_hot_path(lm, manifest.schedule, HotPath::Device)));
    }
    Ok(engines)
}

fn policy_for(engine: &Engine, spec: &str, steps: usize) -> Box<dyn ReusePolicy> {
    build_policy(spec, &engine.model().info, steps).unwrap()
}

fn standalone(engine: &Engine, req: &Request, spec: &str) -> RunResult {
    let steps = req.steps.unwrap_or(engine.model().info.steps);
    let mut pol = policy_for(engine, spec, steps);
    engine.generate(req, pol.as_mut(), None).unwrap()
}

fn lane_bytes(engine: &Engine) -> u64 {
    let m = engine.model();
    let [f, p, _] = m.state_dims();
    let [_, _, c_lat] = m.latent_dims();
    (f * p * c_lat * 4) as u64
}

#[test]
fn device_pool_replicas_have_isolated_transfer_stats() {
    // No artifacts needed: the pool is pure runtime state.
    let pool = DevicePool::cpu(2).unwrap();
    let before = pool.transfer_snapshots();
    assert_eq!(before.len(), 2);

    let t = pool.device(0).upload(&[1.0f32, 2.0, 3.0, 4.0], &[4]).unwrap();
    let mut back = vec![0.0f32; 4];
    pool.device(0).download_into(&t, &mut back).unwrap();
    assert_eq!(back, vec![1.0, 2.0, 3.0, 4.0]);

    let after = pool.transfer_snapshots();
    let d0 = after[0].delta_since(&before[0]);
    assert_eq!(d0.h2d_calls, 1);
    assert_eq!(d0.h2d_bytes, 16);
    assert_eq!(d0.d2h_calls, 1);
    assert_eq!(d0.d2h_bytes, 16);
    // replica 1 saw none of replica 0's traffic
    assert_eq!(after[1], before[1], "replica 1's meter moved without traffic");
}

#[test]
fn migrated_session_matches_never_migrated_run_and_charges_one_lane_per_hop() {
    // Steal-correctness property: a session migrated between replicas at
    // random step boundaries (possibly round-tripping back) finishes with
    // latents ≤1e-6 of its never-migrated oracle, identical reuse
    // decisions, and a RunStats byte model charged exactly one extra lane
    // download+upload per hop.
    if !artifacts_present() {
        return;
    }
    let engines = AssertUnwindSafe(two_engines().unwrap());
    let lane = lane_bytes(&engines[0]);

    proptest_cases(4, move |g| {
        let steps = g.usize_in(4..=10);
        let seed = g.usize_in(0..=10_000) as u64;
        let spec = *g.pick(&[
            "none",
            "static",
            "foresight:n=1,r=2,gamma=0.5",
            "forecast:k=2,inner=static",
            "forecast:k=3,inner=foresight:n=1,r=2,gamma=0.5",
        ]);
        // one or two hops, at strictly increasing interior boundaries
        let hop1 = g.usize_in(1..=steps - 1);
        let hops: Vec<usize> = if g.bool() && hop1 + 1 <= steps - 1 {
            vec![hop1, g.usize_in(hop1 + 1..=steps - 1)]
        } else {
            vec![hop1]
        };

        let mut req = Request::new("a storm front rolling over wheat fields", seed);
        req.steps = Some(steps);
        let oracle = standalone(&engines[0], &req, spec);

        let pol = policy_for(&engines[0], spec, steps);
        let mut sess = engines[0].admit(&req, pol).unwrap();
        let mut at = 0usize; // engine ordinal currently hosting the session
        let mut cursor = 0usize;
        for &hop in &hops {
            while cursor < hop {
                sess.step(None).unwrap();
                cursor += 1;
            }
            at = 1 - at;
            sess.migrate(&engines[at]).unwrap();
        }
        while !sess.is_done() {
            sess.step(None).unwrap();
        }
        let got = sess.finish().unwrap();

        let mismatch = first_latent_mismatch(&got.latents.data, &oracle.latents.data, 1e-6);
        prop_assert(
            mismatch.is_none(),
            format!(
                "steps={steps} spec={spec} hops={hops:?}: latents diverged ({mismatch:?})"
            ),
        );
        prop_assert(
            (got.stats.computed_units, got.stats.reused_units)
                == (oracle.stats.computed_units, oracle.stats.reused_units),
            format!("steps={steps} spec={spec} hops={hops:?}: decisions diverged"),
        );
        // History rings must survive the hop bit-exact: a lost or
        // truncated ring would demote post-hop forecasts to fallbacks.
        prop_assert(
            (got.stats.forecast_units, got.stats.forecast_fallback_units)
                == (oracle.stats.forecast_units, oracle.stats.forecast_fallback_units),
            format!(
                "steps={steps} spec={spec} hops={hops:?}: forecast accounting \
                 diverged (got {}/{} vs oracle {}/{})",
                got.stats.forecast_units,
                got.stats.forecast_fallback_units,
                oracle.stats.forecast_units,
                oracle.stats.forecast_fallback_units,
            ),
        );
        let h = hops.len() as u64;
        prop_assert(
            got.stats.d2h_bytes == oracle.stats.d2h_bytes + h * lane
                && got.stats.d2h_calls == oracle.stats.d2h_calls + h
                && got.stats.h2d_bytes == oracle.stats.h2d_bytes + h * lane
                && got.stats.h2d_calls == oracle.stats.h2d_calls + h,
            format!(
                "steps={steps} hops={hops:?}: migration must charge exactly one lane \
                 down+up per hop (lane={lane}B): got h2d {}B/{} d2h {}B/{} vs oracle \
                 h2d {}B/{} d2h {}B/{}",
                got.stats.h2d_bytes,
                got.stats.h2d_calls,
                got.stats.d2h_bytes,
                got.stats.d2h_calls,
                oracle.stats.h2d_bytes,
                oracle.stats.h2d_calls,
                oracle.stats.d2h_bytes,
                oracle.stats.d2h_calls,
            ),
        );
    });
}

#[test]
fn migrating_a_forecast_session_moves_exactly_the_history_ring_bytes() {
    // The migration drain moves history rings alongside live entries, and
    // the bus-level charge grows by exactly the drained history bytes.
    // RunStats intentionally sees none of this — cache and ring movement
    // is infrastructure traffic, not part of the request's standalone byte
    // model — so the observable is each runtime's own TransferStats. An
    // A/B pair runs the same static schedule migrated at the same
    // boundary, with and without a forecast wrapper: the source bus must
    // differ by the ring bytes, the target bus by the ring bytes plus the
    // k rank-0 coefficient re-uploads (4 bytes each) from the LMS rebuild.
    if !artifacts_present() {
        return;
    }
    let engines = two_engines().unwrap();
    let steps = 8usize;
    let hop = 5usize; // static r=2 computes at 0,2,4 → 3 stores/site pre-hop
    let k = 3usize; // rings full at the hop: min(3-1, k-1) = 2 entries/site

    let mut req = Request::new("history ring hop probe", 33);
    req.steps = Some(steps);

    let run_migrated = |spec: &str| {
        let pol = policy_for(&engines[0], spec, steps);
        let mut sess = engines[0].admit(&req, pol).unwrap();
        for _ in 0..hop {
            sess.step(None).unwrap();
        }
        let src0 = engines[0].model().runtime().transfer_stats().snapshot();
        let dst0 = engines[1].model().runtime().transfer_stats().snapshot();
        sess.migrate(&engines[1]).unwrap();
        let src = engines[0].model().runtime().transfer_stats().snapshot().delta_since(&src0);
        let dst = engines[1].model().runtime().transfer_stats().snapshot().delta_since(&dst0);
        while !sess.is_done() {
            sess.step(None).unwrap();
        }
        (sess.finish().unwrap(), src, dst)
    };

    let fc_spec = format!("forecast:k={k},inner=static:n=1,r=2");
    let (got_fc, src_fc, dst_fc) = run_migrated(&fc_spec);
    let (got_rp, src_rp, dst_rp) = run_migrated("static:n=1,r=2");

    // The replay twin carries no rings and never forecasts.
    assert_eq!(
        (got_rp.stats.forecast_units, got_rp.stats.forecast_fallback_units),
        (0, 0),
        "replay twin must not forecast"
    );
    // Post-hop reuse steps (5 and 7) must be served from the migrated
    // rings, not demoted to fallback replay.
    assert!(
        got_fc.stats.forecast_units > 0,
        "no forecast fired after the hop — rings were lost in migration"
    );

    // Migrated forecast run matches its never-migrated oracle: latents
    // ≤1e-6 and identical forecast/fallback accounting, i.e. the rings
    // round-tripped bit-exact.
    let oracle = standalone(&engines[0], &req, &fc_spec);
    let mismatch = first_latent_mismatch(&got_fc.latents.data, &oracle.latents.data, 1e-6);
    assert!(
        mismatch.is_none(),
        "forecast latents diverged after migration: {mismatch:?}"
    );
    assert_eq!(
        (got_fc.stats.forecast_units, got_fc.stats.forecast_fallback_units),
        (oracle.stats.forecast_units, oracle.stats.forecast_fallback_units),
        "forecast accounting diverged after migration"
    );

    // Exact bus deltas: every coarse site (2 branches × layers ×
    // {spatial, temporal}) drains min(stores-1, k-1) = 2 superseded block
    // outputs of f·p·d·4 bytes each, one metered call apiece.
    let m = engines[0].model();
    let [f, p, d] = m.state_dims();
    let site_bytes = (f * p * d * 4) as u64;
    let sites = (2 * m.info.layers * 2) as u64;
    let ring_entries = (k - 1) as u64;
    let history_bytes = sites * ring_entries * site_bytes;
    let history_calls = sites * ring_entries;

    assert_eq!(
        (src_fc.d2h_bytes, src_fc.d2h_calls),
        (src_rp.d2h_bytes + history_bytes, src_rp.d2h_calls + history_calls),
        "source bus must drain exactly the history-ring bytes on top of the replay twin"
    );
    assert_eq!(
        (dst_fc.h2d_bytes, dst_fc.h2d_calls),
        (
            dst_rp.h2d_bytes + history_bytes + 4 * k as u64,
            dst_rp.h2d_calls + history_calls + k as u64
        ),
        "target bus must restore exactly the history-ring bytes plus k coefficient scalars"
    );
}

#[test]
fn migrate_rejects_same_device_and_shape_mismatch() {
    if !artifacts_present() {
        return;
    }
    let engines = two_engines().unwrap();
    let mut req = Request::new("reject probe", 7);
    req.steps = Some(4);
    let pol = policy_for(&engines[0], "none", 4);
    let mut sess = engines[0].admit(&req, pol).unwrap();
    sess.step(None).unwrap();
    // same engine: refused without poisoning
    assert!(sess.migrate(&engines[0]).is_err());
    // still healthy: finish the run on its own device
    while !sess.is_done() {
        sess.step(None).unwrap();
    }
    sess.finish().unwrap();
}

fn gen_req(bucket: &str, policy: &str, prompt: &str, seed: u64, steps: usize) -> Json {
    Json::obj(vec![
        ("op", Json::str("generate")),
        ("model", Json::str(MODEL.0)),
        ("bucket", Json::str(bucket)),
        ("policy", Json::str(policy)),
        ("prompt", Json::str(prompt)),
        ("seed", Json::num(seed as f64)),
        ("steps", Json::num(steps as f64)),
    ])
}

#[test]
fn refused_migration_leaves_session_healthy_and_matching_its_oracle() {
    // Precheck refusals (here: a shape-bucket mismatch) must NOT poison
    // the session — only a failure mid-transfer does. The scheduler
    // relies on this split: a refused give-back keeps serving the lane
    // locally, while a poisoned lane is swept and its client answered.
    // After the refusal the session keeps stepping on its own device and
    // finishes bit-identical to a never-migrated run, with not one byte
    // of migration traffic charged.
    if !artifacts_present() {
        return;
    }
    let manifest = Manifest::load(&Manifest::default_root()).unwrap();
    let pool = DevicePool::cpu(2).unwrap();
    let mut engines = Vec::with_capacity(2);
    for (rt, bucket) in pool.devices().iter().zip([MODEL.1, "240p-4s"]) {
        let lm = Arc::new(LoadedModel::load(rt.clone(), &manifest, MODEL.0, bucket).unwrap());
        engines.push(Arc::new(Engine::with_hot_path(lm, manifest.schedule, HotPath::Device)));
    }

    let spec = "foresight:n=1,r=2,gamma=0.5";
    let mut req = Request::new("refusal probe", 21);
    req.steps = Some(6);
    let oracle = standalone(&engines[0], &req, spec);

    let pol = policy_for(&engines[0], spec, 6);
    let mut sess = engines[0].admit(&req, pol).unwrap();
    sess.step(None).unwrap();
    sess.step(None).unwrap();
    // wrong shape bucket on the target replica: refused up front
    assert!(sess.migrate(&engines[1]).is_err());
    assert!(
        !sess.is_poisoned(),
        "a refused migration must not poison the session"
    );
    while !sess.is_done() {
        sess.step(None).unwrap();
    }
    let got = sess.finish().unwrap();

    let mismatch = first_latent_mismatch(&got.latents.data, &oracle.latents.data, 1e-6);
    assert!(
        mismatch.is_none(),
        "latents diverged after a refused migration: {mismatch:?}"
    );
    assert_eq!(
        (got.stats.computed_units, got.stats.reused_units),
        (oracle.stats.computed_units, oracle.stats.reused_units),
        "reuse decisions diverged after a refused migration"
    );
    // no hop was charged: the byte model matches the oracle exactly
    assert_eq!(
        (got.stats.h2d_bytes, got.stats.h2d_calls, got.stats.d2h_bytes, got.stats.d2h_calls),
        (
            oracle.stats.h2d_bytes,
            oracle.stats.h2d_calls,
            oracle.stats.d2h_bytes,
            oracle.stats.d2h_calls
        ),
        "a refused migration must not move any lane bytes"
    );
}

#[test]
fn server_steals_a_lane_to_an_idle_replica_and_reports_it() {
    // End-to-end work steal: device 0 runs a two-lane cohort while device
    // 1 goes idle; the scheduler migrates one session over, the response
    // stays bit-compatible with a solo run, and the `stats` op reports the
    // sharded topology (devices, steals, per_device).
    if !artifacts_present() {
        return;
    }
    let manifest = Manifest::load(&Manifest::default_root()).unwrap();
    let pool = Arc::new(DevicePool::cpu(2).unwrap());
    let pairs = vec![
        (MODEL.0.to_string(), MODEL.1.to_string()),
        (MODEL.0.to_string(), "240p-4s".to_string()),
    ];
    let registry = Arc::new(EngineRegistry::load_pool(pool, &manifest, &pairs).unwrap());
    let server = Server::start(
        registry,
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            devices: 2,
            max_batch: 4,
            admit_window_ms: 0,
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let addr = server.addr();

    // Solo references (sequential, before the contended phase).
    let (ref_x, ref_z) = {
        let mut c = Client::connect(&addr).unwrap();
        let rx = c.call(&gen_req(MODEL.1, "foresight", "steal long x", 11, 40)).unwrap();
        assert_eq!(rx.get("status").unwrap().as_str().unwrap(), "ok", "{rx}");
        let rz = c.call(&gen_req(MODEL.1, "foresight", "steal joiner z", 12, 40)).unwrap();
        assert_eq!(rz.get("status").unwrap().as_str().unwrap(), "ok", "{rz}");
        (
            rx.get("latent_l2").unwrap().as_f64().unwrap(),
            rz.get("latent_l2").unwrap().as_f64().unwrap(),
        )
    };

    let wait_lanes = |c: &mut Client, want: usize| {
        let t0 = std::time::Instant::now();
        loop {
            let s = c.call(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
            if s.get("lanes_active").unwrap().as_usize().unwrap() >= want {
                return;
            }
            assert!(
                t0.elapsed() < std::time::Duration::from_secs(20),
                "never reached {want} active lanes: {s}"
            );
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
    };
    let mut c = Client::connect(&addr).unwrap();

    // jobX: long request, lands on device 0 (least-loaded, lowest ordinal).
    let job_x = gen_req(MODEL.1, "foresight", "steal long x", 11, 40);
    let mut cx = Client::connect(&addr).unwrap();
    let hx = std::thread::spawn(move || cx.call(&job_x).unwrap());
    wait_lanes(&mut c, 1);
    std::thread::sleep(std::time::Duration::from_millis(30));

    // jobY: different bucket, short — keeps device 1 busy while jobZ
    // routes by affinity, then frees it to raise `wants_work`.
    let job_y = gen_req("240p-4s", "none", "steal short y", 13, 6);
    let mut cy = Client::connect(&addr).unwrap();
    let hy = std::thread::spawn(move || cy.call(&job_y).unwrap());
    wait_lanes(&mut c, 2);

    // jobZ: same key as jobX → cohort affinity routes it to device 0,
    // which now holds two lanes; once device 1 idles, one migrates.
    let job_z = gen_req(MODEL.1, "foresight", "steal joiner z", 12, 40);
    let mut cz = Client::connect(&addr).unwrap();
    let hz = std::thread::spawn(move || cz.call(&job_z).unwrap());

    let rx = hx.join().unwrap();
    let ry = hy.join().unwrap();
    let rz = hz.join().unwrap();
    for (name, r) in [("x", &rx), ("y", &ry), ("z", &rz)] {
        assert_eq!(r.get("status").unwrap().as_str().unwrap(), "ok", "job {name}: {r}");
    }
    // Bit-compatibility regardless of which lane migrated.
    let got_x = rx.get("latent_l2").unwrap().as_f64().unwrap();
    let got_z = rz.get("latent_l2").unwrap().as_f64().unwrap();
    assert!(
        (got_x - ref_x).abs() <= 1e-6 * (1.0 + ref_x.abs()),
        "job x diverged after sharded serving: {got_x} vs {ref_x}"
    );
    assert!(
        (got_z - ref_z).abs() <= 1e-6 * (1.0 + ref_z.abs()),
        "job z diverged after sharded serving: {got_z} vs {ref_z}"
    );

    let stats = c.call(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
    assert_eq!(stats.get("devices").unwrap().as_usize().unwrap(), 2, "{stats}");
    assert!(
        stats.get("steals").unwrap().as_usize().unwrap() >= 1,
        "no session migration was recorded: {stats}"
    );
    let per_dev = stats.get("per_device").unwrap().as_arr().unwrap();
    assert_eq!(per_dev.len(), 2, "{stats}");
    let mut dev_steals = 0usize;
    for (i, d) in per_dev.iter().enumerate() {
        assert_eq!(d.get("device").unwrap().as_usize().unwrap(), i, "{stats}");
        assert_eq!(
            d.get("lanes_active").unwrap().as_usize().unwrap(),
            0,
            "lanes must drain on device {i}: {stats}"
        );
        dev_steals += d.get("steals").unwrap().as_usize().unwrap();
        // every replica that served traffic moved bytes over its own bus
        if d.get("retires").unwrap().as_usize().unwrap() > 0 {
            assert!(d.get("h2d_bytes").unwrap().as_f64().unwrap() > 0.0, "{stats}");
        }
    }
    assert_eq!(
        dev_steals,
        stats.get("steals").unwrap().as_usize().unwrap(),
        "per-device steal counts must sum to the aggregate: {stats}"
    );
    server.shutdown();
}
