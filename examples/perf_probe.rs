//! Internal perf probe: per-executable time breakdown for one baseline run.
//! (Used by the EXPERIMENTS.md §Perf iterations; not part of the public API.)
use foresight::bench_support::{run_one, BenchCtx};

fn main() -> anyhow::Result<()> {
    let mut ctx = BenchCtx::new()?;
    let bucket = std::env::args().nth(1).unwrap_or("240p-2s".into());
    let engine = ctx.engine("opensora-sim", &bucket)?;
    let _ = run_one(&engine, "none", "warmup", 0, Some(2))?;
    engine.model().reset_op_stats();
    let t0 = std::time::Instant::now();
    let r = run_one(&engine, "none", "a lighthouse at dusk", 1, None)?;
    let wall = t0.elapsed().as_secs_f64();
    let mut stats = engine.model().op_stats();
    stats.sort_by(|a, b| b.2.total_cmp(&a.2));
    let exec_total: f64 = stats.iter().map(|s| s.2).sum();
    println!("bucket {bucket}: wall {wall:.3}s, engine-reported {:.3}s, exec total {exec_total:.3}s, non-exec {:.3}s", r.stats.wall_s, wall - exec_total);
    for (name, calls, secs) in stats {
        if calls > 0 {
            println!("  {name:20} {calls:6} calls {secs:8.3}s  ({:.3} ms/call)", 1e3 * secs / calls as f64);
        }
    }
    Ok(())
}
