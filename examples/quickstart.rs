//! Quickstart: generate one video with Foresight and compare it against
//! the no-reuse baseline — the 30-line tour of the public API.
//!
//! Run with: `cargo run --release --example quickstart`
//! (requires `make artifacts` first.)

use std::sync::Arc;

use foresight::config::Manifest;
use foresight::engine::{Engine, Request};
use foresight::metrics::{Decoder, FeatureNet, QualityReport};
use foresight::model::LoadedModel;
use foresight::policy::build_policy;
use foresight::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // 1. Load the AOT artifacts (HLO text + weights) onto the PJRT client.
    let manifest = Manifest::load(&Manifest::default_root())?;
    let rt = Arc::new(Runtime::cpu()?);
    let model = Arc::new(LoadedModel::load(rt, &manifest, "opensora-sim", "240p-2s")?);
    let engine = Engine::new(model.clone(), manifest.schedule);
    let info = &model.info;

    let prompt = "a playful black labrador in a pumpkin costume frolics \
                  through a sunlit autumn garden, leaves swirling";
    let req = Request::new(prompt, 42);

    // 2. Baseline: every block computed at every step.
    let mut baseline_policy = build_policy("none", info, info.steps)?;
    let baseline = engine.generate(&req, baseline_policy.as_mut(), None)?;

    // 3. Foresight: adaptive per-layer reuse (paper defaults N=1, R=2,
    //    gamma=0.5, 15% warmup).
    let mut fs_policy = build_policy("foresight", info, info.steps)?;
    let fs = engine.generate(&req, fs_policy.as_mut(), None)?;

    // 4. Decode latents and measure quality relative to the baseline.
    let bucket = info.bucket("240p-2s")?;
    let dec = Decoder::new(bucket.ph, bucket.pw, info.latent_channels);
    let net = FeatureNet::new();
    let q = QualityReport::compare(&net, &dec.decode(&baseline.latents), &dec.decode(&fs.latents));

    println!("prompt   : {prompt}");
    println!();
    println!("baseline : {:.2}s ({} blocks computed)", baseline.stats.wall_s, baseline.stats.computed_units);
    println!(
        "foresight: {:.2}s ({} computed, {} reused = {:.0}%)",
        fs.stats.wall_s,
        fs.stats.computed_units,
        fs.stats.reused_units,
        100.0 * fs.stats.reuse_fraction()
    );
    println!("speedup  : {:.2}x", baseline.stats.wall_s / fs.stats.wall_s);
    println!();
    println!("quality vs baseline:");
    println!("  PSNR  : {:.2} dB", q.psnr);
    println!("  SSIM  : {:.3}", q.ssim);
    println!("  LPIPS*: {:.4}  (*random-feature proxy)", q.lpips);
    println!("  VBench*: {:.2}%", q.vbench);
    println!();
    println!(
        "cache: {:.0} KiB peak, {:.0} entries/layer (coarse 2LHWF)",
        fs.stats.cache_peak_bytes as f64 / 1024.0,
        fs.stats.cache_entries_per_layer
    );
    Ok(())
}
