//! Policy comparison: run all six reuse policies on the same prompts and
//! print a Table 1-shaped comparison (latency, speedup, reuse fraction,
//! PSNR/SSIM/LPIPS vs. baseline).
//!
//! Run with: `cargo run --release --example policy_compare`

use std::sync::Arc;

use foresight::config::Manifest;
use foresight::engine::{Engine, Request};
use foresight::metrics::{Decoder, FeatureNet, QualityReport};
use foresight::model::LoadedModel;
use foresight::policy::build_policy;
use foresight::runtime::Runtime;
use foresight::util::benchkit::MdTable;

const PROMPTS: [&str; 3] = [
    "a calm lake at dawn, soft golden light, mist drifting slowly",
    "a drone camera racing along crashing waves as a storm swirls",
    "a chef slicing vegetables in a quiet kitchen, steady close-up",
];

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&Manifest::default_root())?;
    let rt = Arc::new(Runtime::cpu()?);
    let model = Arc::new(LoadedModel::load(rt, &manifest, "opensora-sim", "240p-2s")?);
    let engine = Engine::new(model.clone(), manifest.schedule);
    let info = model.info.clone();
    let bucket = info.bucket("240p-2s")?.clone();
    let dec = Decoder::new(bucket.ph, bucket.pw, info.latent_channels);
    let net = FeatureNet::new();

    // Baselines per prompt (also warms the runtime).
    let mut baselines = Vec::new();
    for (i, prompt) in PROMPTS.iter().enumerate() {
        let mut p = build_policy("none", &info, info.steps)?;
        let r = engine.generate(&Request::new(prompt, 100 + i as u64), p.as_mut(), None)?;
        baselines.push(r);
    }
    let base_lat: f64 =
        baselines.iter().map(|r| r.stats.wall_s).sum::<f64>() / baselines.len() as f64;

    let mut table = MdTable::new(&[
        "Method", "Latency(s)", "Speedup", "Reuse%", "PSNR", "SSIM", "LPIPS*",
    ]);
    table.row(vec![
        "baseline".into(),
        format!("{base_lat:.2}"),
        "1.00x".into(),
        "0".into(),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);

    for spec in [
        "static",
        "delta-dit",
        "tgate",
        "pab",
        "foresight:n=1,r=2",
        "foresight:n=2,r=3",
    ] {
        let mut lat = 0.0;
        let mut reuse = 0.0;
        let (mut psnr, mut ssim, mut lpips) = (0.0, 0.0, 0.0);
        for (i, prompt) in PROMPTS.iter().enumerate() {
            let mut p = build_policy(spec, &info, info.steps)?;
            let r = engine.generate(&Request::new(prompt, 100 + i as u64), p.as_mut(), None)?;
            lat += r.stats.wall_s;
            reuse += r.stats.reuse_fraction();
            let q = QualityReport::compare(
                &net,
                &dec.decode(&baselines[i].latents),
                &dec.decode(&r.latents),
            );
            psnr += q.psnr;
            ssim += q.ssim;
            lpips += q.lpips;
        }
        let n = PROMPTS.len() as f64;
        lat /= n;
        table.row(vec![
            spec.into(),
            format!("{lat:.2}"),
            format!("{:.2}x", base_lat / lat),
            format!("{:.0}", 100.0 * reuse / n),
            format!("{:.2}", psnr / n),
            format!("{:.3}", ssim / n),
            format!("{:.4}", lpips / n),
        ]);
    }

    println!("\nPolicy comparison — opensora-sim @ 240p-2s, {} prompts\n", PROMPTS.len());
    println!("{}", table.to_markdown());
    println!("(*LPIPS is the random-feature proxy; see DESIGN.md §1)");
    Ok(())
}
