//! Adaptive-behaviour analysis: visualises what Foresight actually decides
//! on a real request — the per-layer thresholds (paper Fig. 5) and the
//! compute/reuse map over layers × steps (paper Fig. 6) — as ASCII art.
//!
//! Run with: `cargo run --release --example adaptive_analysis`

use std::sync::Arc;

use foresight::config::Manifest;
use foresight::engine::{Engine, Request};
use foresight::model::{BlockKind, LoadedModel};
use foresight::policy::build_policy;
use foresight::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&Manifest::default_root())?;
    let rt = Arc::new(Runtime::cpu()?);
    let model = Arc::new(LoadedModel::load(rt, &manifest, "opensora-sim", "240p-2s")?);
    let engine = Engine::new(model.clone(), manifest.schedule);
    let info = model.info.clone();

    let prompt = "a playful black labrador in a pumpkin halloween costume \
                  bounds joyfully across a leaf-strewn lawn";
    let mut policy = build_policy("foresight:n=1,r=2,gamma=0.5,warmup=0.15", &info, info.steps)?;
    let run = engine.generate(&Request::new(prompt, 7), policy.as_mut(), None)?;

    println!("prompt: {prompt}\n");
    println!(
        "policy {} — wall {:.2}s, reuse {:.0}%\n",
        run.stats.policy,
        run.stats.wall_s,
        100.0 * run.stats.reuse_fraction()
    );

    // --- Fig. 5: per-layer thresholds -------------------------------------
    let th = run.thresholds.expect("foresight thresholds");
    println!("reuse thresholds λ (cond branch)   spatial      temporal");
    for layer in 0..info.layers {
        let s = th.get(&(layer, BlockKind::Spatial, 0)).copied().unwrap_or(0.0);
        let t = th.get(&(layer, BlockKind::Temporal, 0)).copied().unwrap_or(0.0);
        let bar = |v: f64| "#".repeat(((v * 2e3).min(28.0)) as usize);
        println!("  layer {layer:2}  {s:9.2e} {:<14} {t:9.2e} {}", bar(s), bar(t));
    }

    // --- Fig. 6: reuse map over layers × steps ----------------------------
    // sites in order: (layer, spatial), (layer, temporal) per layer
    println!("\nreuse map (rows = blocks, cols = steps; '·' compute, '█' reuse)");
    let n_sites = info.layers * 2;
    for site in 0..n_sites {
        let layer = site / 2;
        let kind = if site % 2 == 0 { "S" } else { "T" };
        let row: String = run
            .reuse_map
            .iter()
            .map(|step| if step[site] { '█' } else { '·' })
            .collect();
        println!("  L{layer:02}{kind} {row}");
    }
    println!(
        "\n(warmup = first {} steps; refresh every R steps; later layers \
         recompute more often — the paper's Fig. 6 pattern)",
        ((info.steps as f64) * 0.15).round() as usize
    );
    Ok(())
}
