//! End-to-end serving driver (the DESIGN.md validation workload): starts
//! the TCP JSON-lines server in-process, drives it with concurrent client
//! connections sending a mixed policy workload, and reports latency /
//! throughput — proving all three layers compose: Pallas kernels inside
//! AOT HLO executables (L1/L2), dispatched by the Rust coordinator's
//! router + worker pool (L3), with Python nowhere on the request path.
//!
//! Run with: `cargo run --release --example serve`

use std::sync::Arc;
use std::time::Instant;

use foresight::config::Manifest;
use foresight::runtime::Runtime;
use foresight::server::{Client, EngineRegistry, Server, ServerConfig};
use foresight::util::json::Json;
use foresight::util::stats;
use foresight::workload;

const CLIENTS: usize = 4;
const REQUESTS_PER_CLIENT: usize = 3;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&Manifest::default_root())?;
    let rt = Arc::new(Runtime::cpu()?);
    println!("loading engines on PJRT ({}) ...", rt.platform());
    let registry = Arc::new(EngineRegistry::load(
        rt,
        &manifest,
        &[("opensora-sim".to_string(), "240p-2s".to_string())],
    )?);
    // Default config: micro-batching on (max_batch 4, short gather window)
    // — concurrent same-policy clients coalesce into shared engine passes.
    let server = Server::start(
        registry,
        ServerConfig { addr: "127.0.0.1:0".into(), workers: 2, ..ServerConfig::default() },
    )?;
    let addr = server.addr();
    println!("server up on {addr}; {CLIENTS} clients × {REQUESTS_PER_CLIENT} requests\n");

    let prompts = workload::vbench_prompts(2);
    let policies = ["foresight", "static", "foresight:n=2,r=3", "pab"];

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for cid in 0..CLIENTS {
        let prompts: Vec<String> = prompts.iter().map(|p| p.text.clone()).collect();
        handles.push(std::thread::spawn(move || -> anyhow::Result<Vec<(f64, f64, f64, f64)>> {
            let mut client = Client::connect(&addr)?;
            assert!(client.ping()?);
            let mut out = Vec::new();
            for i in 0..REQUESTS_PER_CLIENT {
                let idx = cid * REQUESTS_PER_CLIENT + i;
                let req = Json::obj(vec![
                    ("op", Json::str("generate")),
                    ("model", Json::str("opensora-sim")),
                    ("bucket", Json::str("240p-2s")),
                    ("policy", Json::str(policies[idx % policies.len()])),
                    ("prompt", Json::str(&prompts[idx % prompts.len()])),
                    ("seed", Json::num(idx as f64)),
                ]);
                let t = Instant::now();
                let resp = client.call(&req)?;
                let e2e = t.elapsed().as_secs_f64();
                anyhow::ensure!(
                    resp.get("status").and_then(|s| s.as_str()) == Some("ok"),
                    "request failed: {resp}"
                );
                let wall = resp.get("wall_s").and_then(|v| v.as_f64()).unwrap_or(0.0);
                let queue = resp.get("queue_s").and_then(|v| v.as_f64()).unwrap_or(0.0);
                let batch = resp.get("batch_size").and_then(|v| v.as_f64()).unwrap_or(1.0);
                out.push((e2e, wall, queue, batch));
            }
            Ok(out)
        }));
    }

    let mut e2e = Vec::new();
    let mut exec = Vec::new();
    let mut queued = Vec::new();
    let mut batch_sizes = Vec::new();
    for h in handles {
        for (a, b, c, d) in h.join().expect("client thread")? {
            e2e.push(a);
            exec.push(b);
            queued.push(c);
            batch_sizes.push(d);
        }
    }
    let total_s = t0.elapsed().as_secs_f64();
    let n = e2e.len();

    // server-side stats
    let mut client = Client::connect(&addr)?;
    let sstats = client.call(&Json::obj(vec![("op", Json::str("stats"))]))?;

    println!("completed {n} requests in {total_s:.2}s");
    println!("throughput        : {:.2} videos/min", n as f64 * 60.0 / total_s);
    println!(
        "e2e latency       : p50 {:.2}s  p95 {:.2}s  mean {:.2}s",
        stats::percentile(&e2e, 50.0),
        stats::percentile(&e2e, 95.0),
        stats::mean(&e2e)
    );
    println!(
        "execution latency : p50 {:.2}s  mean {:.2}s",
        stats::percentile(&exec, 50.0),
        stats::mean(&exec)
    );
    println!("queueing          : mean {:.2}s", stats::mean(&queued));
    println!("batch size        : mean {:.2}", stats::mean(&batch_sizes));
    println!("server stats      : {sstats}");

    let _ = client.call(&Json::obj(vec![("op", Json::str("shutdown"))]));
    server.shutdown();
    println!("\nserver stopped cleanly");
    Ok(())
}
