//! End-to-end serving driver (the DESIGN.md validation workload): starts
//! the TCP JSON-lines server in-process, drives it with concurrent client
//! connections sending a mixed policy workload, and reports latency /
//! throughput — proving all three layers compose: Pallas kernels inside
//! AOT HLO executables (L1/L2), dispatched by the Rust coordinator's
//! router + worker pool (L3), with Python nowhere on the request path.
//!
//! Also demonstrates the autotune lifecycle end to end: a tuned
//! [`ProfileStore`] is written to disk, loaded back (exactly what
//! `foresight serve --profiles <path>` does), and part of the client
//! traffic requests `policy: "auto"` — resolved to the tuned spec before
//! batching, with the resolution echoed in each response and counted in
//! the server stats.
//!
//! Run with: `cargo run --release --example serve`

use std::sync::Arc;
use std::time::Instant;

use foresight::autotune::{ProfileKey, ProfileStore, TunedProfile};
use foresight::config::Manifest;
use foresight::runtime::Runtime;
use foresight::server::{Client, EngineRegistry, Server, ServerConfig};
use foresight::util::json::Json;
use foresight::util::stats;
use foresight::workload;

const CLIENTS: usize = 4;
const REQUESTS_PER_CLIENT: usize = 3;

/// Write a tuned profile store to disk and load it back — the same file
/// `foresight autotune --out <path>` produces and `serve --profiles
/// <path>` consumes. (A real deployment would run the `autotune`
/// subcommand; the fixed spec here keeps the example fast.)
fn demo_profiles(manifest: &Manifest) -> anyhow::Result<Arc<ProfileStore>> {
    let info = manifest.model("opensora-sim")?;
    let mut store = ProfileStore::new();
    store.insert(TunedProfile {
        key: ProfileKey {
            model: "opensora-sim".into(),
            bucket: "240p-2s".into(),
            sampler: info.sampler.name().into(),
            steps: info.steps,
        },
        spec: "foresight:n=2,r=3,gamma=0.5,warmup=0.15".into(),
        min_psnr: 30.0,
        profile_version: 1,
        frontier: vec![],
    });
    let path = std::env::temp_dir().join("foresight-serve-example-profiles.json");
    store.save(&path)?;
    let loaded = ProfileStore::load(&path)?;
    println!(
        "profile store: {} profile(s), version {} (via {})",
        loaded.len(),
        loaded.version(),
        path.display()
    );
    Ok(Arc::new(loaded))
}

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&Manifest::default_root())?;
    let rt = Arc::new(Runtime::cpu()?);
    println!("loading engines on PJRT ({}) ...", rt.platform());
    let profiles = demo_profiles(&manifest)?;
    let registry = Arc::new(EngineRegistry::load(
        rt,
        &manifest,
        &[("opensora-sim".to_string(), "240p-2s".to_string())],
    )?);
    // Default config: continuous step-level batching (max_batch 4, no
    // admission window) — concurrent clients coalesce into shared device
    // passes at step boundaries even across different policies/steps, and
    // late arrivals join in-flight cohorts instead of queueing behind
    // them.
    let server = Server::start(
        registry,
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: 2,
            profiles: Some(profiles),
            ..ServerConfig::default()
        },
    )?;
    let addr = server.addr();
    println!("server up on {addr}; {CLIENTS} clients × {REQUESTS_PER_CLIENT} requests\n");

    let prompts = workload::vbench_prompts(2);
    // `auto` rides alongside explicit specs: it resolves through the
    // loaded profile store before the batch key is formed.
    let policies = ["auto", "foresight", "static", "auto"];

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for cid in 0..CLIENTS {
        let prompts: Vec<String> = prompts.iter().map(|p| p.text.clone()).collect();
        handles.push(std::thread::spawn(move || -> anyhow::Result<Vec<(f64, f64, f64, f64)>> {
            let mut client = Client::connect(&addr)?;
            assert!(client.ping()?);
            let mut out = Vec::new();
            for i in 0..REQUESTS_PER_CLIENT {
                let idx = cid * REQUESTS_PER_CLIENT + i;
                let policy = policies[idx % policies.len()];
                let req = Json::obj(vec![
                    ("op", Json::str("generate")),
                    ("model", Json::str("opensora-sim")),
                    ("bucket", Json::str("240p-2s")),
                    ("policy", Json::str(policy)),
                    ("prompt", Json::str(&prompts[idx % prompts.len()])),
                    ("seed", Json::num(idx as f64)),
                ]);
                let t = Instant::now();
                let resp = client.call(&req)?;
                let e2e = t.elapsed().as_secs_f64();
                anyhow::ensure!(
                    resp.get("status").and_then(|s| s.as_str()) == Some("ok"),
                    "request failed: {resp}"
                );
                if policy == "auto" && idx == 0 {
                    println!(
                        "auto resolution: {} (match {}, profile v{})",
                        resp.get("resolved_policy").and_then(|v| v.as_str()).unwrap_or("?"),
                        resp.get("profile_match").and_then(|v| v.as_str()).unwrap_or("?"),
                        resp.get("profile_version").and_then(|v| v.as_f64()).unwrap_or(0.0),
                    );
                }
                let wall = resp.get("wall_s").and_then(|v| v.as_f64()).unwrap_or(0.0);
                let queue = resp.get("queue_s").and_then(|v| v.as_f64()).unwrap_or(0.0);
                let batch = resp.get("batch_size").and_then(|v| v.as_f64()).unwrap_or(1.0);
                out.push((e2e, wall, queue, batch));
            }
            Ok(out)
        }));
    }

    let mut e2e = Vec::new();
    let mut exec = Vec::new();
    let mut queued = Vec::new();
    let mut batch_sizes = Vec::new();
    for h in handles {
        for (a, b, c, d) in h.join().expect("client thread")? {
            e2e.push(a);
            exec.push(b);
            queued.push(c);
            batch_sizes.push(d);
        }
    }
    let total_s = t0.elapsed().as_secs_f64();
    let n = e2e.len();

    // server-side stats
    let mut client = Client::connect(&addr)?;
    let sstats = client.call(&Json::obj(vec![("op", Json::str("stats"))]))?;

    println!("completed {n} requests in {total_s:.2}s");
    println!("throughput        : {:.2} videos/min", n as f64 * 60.0 / total_s);
    println!(
        "e2e latency       : p50 {:.2}s  p95 {:.2}s  mean {:.2}s",
        stats::percentile(&e2e, 50.0),
        stats::percentile(&e2e, 95.0),
        stats::mean(&e2e)
    );
    println!(
        "execution latency : p50 {:.2}s  mean {:.2}s",
        stats::percentile(&exec, 50.0),
        stats::mean(&exec)
    );
    println!("queueing          : mean {:.2}s", stats::mean(&queued));
    println!("batch size        : mean {:.2}", stats::mean(&batch_sizes));
    println!(
        "scheduler         : occupancy mean {:.2} / max {:.0}, {} joins, {} regroups",
        sstats.get("occupancy_mean").and_then(|v| v.as_f64()).unwrap_or(0.0),
        sstats.get("occupancy_max").and_then(|v| v.as_f64()).unwrap_or(0.0),
        sstats.get("joins").and_then(|v| v.as_f64()).unwrap_or(0.0),
        sstats.get("regroups").and_then(|v| v.as_f64()).unwrap_or(0.0),
    );
    println!(
        "auto resolution   : {} tuned / {} fallback (store v{})",
        sstats.get("auto_resolved").and_then(|v| v.as_f64()).unwrap_or(0.0),
        sstats.get("auto_fallbacks").and_then(|v| v.as_f64()).unwrap_or(0.0),
        sstats.get("profile_store_version").and_then(|v| v.as_f64()).unwrap_or(0.0),
    );
    println!("server stats      : {sstats}");

    let _ = client.call(&Json::obj(vec![("op", Json::str("shutdown"))]));
    server.shutdown();
    println!("\nserver stopped cleanly");
    Ok(())
}
